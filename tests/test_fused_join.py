"""The fused rank-packed join pipeline vs the staged oracle.

``fused_sort_merge_join`` must be **bit-identical** to
``sort_merge_join`` — same output rows in the same order, same padding,
same overflow flag — because the staged path is the oracle the fused
kernel is verified against (``join_impl`` selects between them at every
level of the engine).  The curated case matrix always runs; the
randomized sweep additionally runs when hypothesis is installed
(``pip install -e .[dev]``).

Covered hazards, each of which broke a draft of the kernel:

* all-invalid inputs (the rank packing must not let sentinel rows
  alias real keys),
* a *valid* key equal to the int32 sentinel (searchsorted results are
  clamped by the valid count),
* matches exactly at ``out_capacity`` (no overflow) and one past it
  (overflow, same flag as staged),
* the packed-rank int32 overflow bound (large capacity falls back to
  the staged ``lax.sort`` — parity, not divergence).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Relation, SimGrid
from repro.core.local import (_sorted_by_key, fused_sort_merge_join,
                              local_join, partition_ranks, sort_merge_join,
                              sort_rows)
from repro.kernels import fused_join as fj

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

I32_MAX = np.iinfo(np.int32).max


def rel(keys, vals=None, capacity=None, valid=None, key_name="b",
        val_name="v"):
    keys = np.asarray(keys, np.int32)
    n = len(keys)
    cap = capacity if capacity is not None else n
    cols = {key_name: np.zeros(cap, np.int32),
            val_name: np.zeros(cap, np.float32)}
    cols[key_name][:n] = keys
    cols[val_name][:n] = (np.arange(n, dtype=np.float32) + 1.0
                          if vals is None else np.asarray(vals, np.float32))
    v = np.zeros(cap, bool)
    v[:n] = True if valid is None else np.asarray(valid, bool)
    return Relation({k: jnp.asarray(c) for k, c in cols.items()},
                    jnp.asarray(v))


def assert_bit_identical(case, left, right, out_cap, **kw):
    o1, f1 = sort_merge_join(left, right, "b", "b", out_cap, **kw)
    o2, f2 = fused_sort_merge_join(left, right, "b", "b", out_cap, **kw)
    assert bool(f1) == bool(f2), (case, "overflow flag")
    assert o1.names == o2.names, case
    assert np.array_equal(np.asarray(o1.valid), np.asarray(o2.valid)), case
    for name in o1.names:
        assert np.array_equal(np.asarray(o1.cols[name]),
                              np.asarray(o2.cols[name])), (case, name)


CASES = {
    "plain": (rel([3, 1, 4, 1, 5]), rel([1, 1, 2, 3], val_name="w"), 32),
    "empty_left": (rel([], capacity=8), rel([1, 2, 3], val_name="w"), 16),
    "all_invalid": (rel([7, 7, 7], valid=[False] * 3),
                    rel([7, 7], val_name="w"), 16),
    "both_invalid": (rel([2, 2], valid=[False] * 2),
                     rel([2, 2], valid=[False] * 2, val_name="w"), 8),
    "sentinel_key": (rel([I32_MAX, 2, I32_MAX]),
                     rel([I32_MAX, 2], val_name="w"), 16),
    "duplicates": (rel([5] * 8), rel([5] * 8, val_name="w"), 64),
    "no_matches": (rel([1, 2, 3]), rel([4, 5, 6], val_name="w"), 8),
    "holes": (rel([9, 9, 2, 4], capacity=8,
                  valid=[True, False, True, True]),
              rel([9, 2, 2], capacity=6, val_name="w"), 32),
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_fused_bit_identical(case):
    left, right, out_cap = CASES[case]
    assert_bit_identical(case, left, right, out_cap)


def test_fused_exact_capacity_and_overflow():
    # 3 x 4 = 12 matches on key 5: exactly at out_capacity=12 (no
    # overflow), over it at 11 (overflow) — the flags and rows must
    # match staged in both regimes.
    left = rel([5, 5, 5])
    right = rel([5, 5, 5, 5], val_name="w")
    assert_bit_identical("exact_capacity", left, right, 12)
    o, f = fused_sort_merge_join(left, right, "b", "b", 12)
    assert not bool(f) and int(np.sum(np.asarray(o.valid))) == 12
    assert_bit_identical("overflow", left, right, 11)
    _, f = fused_sort_merge_join(left, right, "b", "b", 11)
    assert bool(f)


def test_fused_prefixes_and_presorted():
    left = rel([4, 2, 2, 7])
    right = rel([2, 7, 7], val_name="v")  # name collision: prefixes
    assert_bit_identical("prefixes", left, right, 32,
                         prefix_l="l_", prefix_r="r_")
    ls, rs = sort_rows(left, "b"), sort_rows(right, "b")
    o1, f1 = sort_merge_join(ls, rs, "b", "b", 32, prefix_r="r_",
                             presorted_l=True, presorted_r=True)
    o2, f2 = fused_sort_merge_join(ls, rs, "b", "b", 32, prefix_r="r_",
                                   presorted_l=True, presorted_r=True)
    assert bool(f1) == bool(f2)
    for name in o1.names:
        assert np.array_equal(np.asarray(o1.cols[name]),
                              np.asarray(o2.cols[name])), name


def test_stable_key_order_matches_staged_sort():
    rng = np.random.default_rng(0)
    for n, n_keys in ((1, 1), (7, 3), (64, 5), (128, 128), (257, 11)):
        key = jnp.asarray(rng.integers(0, n_keys, n), jnp.int32)
        valid = jnp.asarray(rng.random(n) < 0.8)
        o1, m1 = _sorted_by_key(key, valid)
        o2, m2 = fj.stable_key_order(key, valid)
        assert np.array_equal(np.asarray(o1), np.asarray(o2)), n
        assert np.array_equal(np.asarray(m1), np.asarray(m2)), n


def test_stable_key_order_packing_fallback():
    # Past the int32 packing bound the fused sort must fall back to the
    # staged lax.sort — identical results either way.
    n = 1 << 16
    rng = np.random.default_rng(1)
    key = jnp.asarray(rng.integers(0, I32_MAX, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.9)
    assert fj._pack_dtype(n, 2 * n) is None  # 2n·n − 1 > int32 max
    o1, m1 = _sorted_by_key(key, valid)
    o2, m2 = fj.stable_key_order(key, valid)
    assert np.array_equal(np.asarray(o1), np.asarray(o2))
    assert np.array_equal(np.asarray(m1), np.asarray(m2))


def test_partition_order_matches_argsort():
    rng = np.random.default_rng(2)
    for n, k in ((1, 1), (16, 4), (100, 7), (256, 16)):
        bucket = jnp.asarray(rng.integers(0, k, n), jnp.int32)
        order = fj.partition_order(bucket, k)
        assert order is not None
        want = jnp.argsort(bucket, stable=True)
        assert np.array_equal(np.asarray(order), np.asarray(want)), (n, k)


def test_partition_ranks_matches_argsort_plan():
    rng = np.random.default_rng(3)
    for n, k in ((1, 1), (64, 8), (200, 13)):
        bucket = jnp.asarray(rng.integers(0, k, n), jnp.int32)
        valid = jnp.asarray(rng.random(n) < 0.7)
        order, sorted_key, rank = partition_ranks(bucket, valid, k)
        # reference plan: plain stable argsort of the same key
        key = np.where(np.asarray(valid), np.asarray(bucket), k)
        want_order = np.argsort(key, kind="stable")
        want_sorted = key[want_order]
        first = np.searchsorted(want_sorted, want_sorted, side="left")
        want_rank = np.arange(n) - first
        assert np.array_equal(np.asarray(order), want_order), (n, k)
        assert np.array_equal(np.asarray(sorted_key), want_sorted), (n, k)
        assert np.array_equal(np.asarray(rank), want_rank), (n, k)


def test_probe_counts_interpret_matches_ref():
    rng = np.random.default_rng(4)
    sorted_keys = jnp.sort(jnp.asarray(rng.integers(0, 40, 128), jnp.int32))
    queries = jnp.asarray(rng.integers(0, 40, 96), jnp.int32)
    lo_r, hi_r = fj.probe_counts(queries, sorted_keys, backend="ref")
    lo_p, hi_p = fj.probe_counts_pallas(queries, sorted_keys, block_q=32,
                                        block_r=32, interpret=True)
    assert np.array_equal(np.asarray(lo_r), np.asarray(lo_p))
    assert np.array_equal(np.asarray(hi_r), np.asarray(hi_p))


def test_local_join_fused_impl():
    rng = np.random.default_rng(5)
    left = rel(rng.integers(0, 10, 40))
    right = rel(rng.integers(0, 10, 30), val_name="w")
    outs = {}
    for impl in ("sort_merge", "fused", "all_pairs"):
        o, f = local_join(left, right, "b", "b", 512, impl=impl)
        assert not bool(f)
        outs[impl] = o.to_tuple_set()
    assert outs["sort_merge"] == outs["fused"] == outs["all_pairs"]


@pytest.mark.parametrize("strategy", ["one_round", "cascade"])
def test_executor_fused_matches_staged(strategy):
    from repro.core import (ChainCaps, JoinQuery, execute_query,
                            query_table_inputs)
    rng = np.random.default_rng(6)
    query = JoinQuery.triangle()
    edges = (rng.integers(0, 14, 50).astype(np.int32),
             rng.integers(0, 14, 50).astype(np.int32))
    shape = (2, 2, 2) if strategy == "one_round" else (4,)
    rels = query_table_inputs(query, [edges] * 3, shape)
    grid = SimGrid(shape)
    caps = ChainCaps(recv=512, mid=4096, out=8192, local=1024)
    results = {}
    for impl in ("sort_merge", "fused"):
        out, st, ovf = execute_query(grid, query, rels, strategy=strategy,
                                     caps=caps, join_impl=impl)
        assert not bool(ovf)
        results[impl] = (out.to_tuple_set(query.attrs),
                         {k: np.asarray(v).tolist() for k, v in st.items()})
    assert results["sort_merge"] == results["fused"]


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(n_left=st.integers(0, 40), n_right=st.integers(0, 40),
           dom=st.integers(1, 12), cap_slack=st.integers(0, 16),
           p_valid=st.floats(0.0, 1.0), seed=st.integers(0, 999))
    def test_fused_bit_identical_random(n_left, n_right, dom, cap_slack,
                                        p_valid, seed):
        rng = np.random.default_rng(seed)
        lk = rng.integers(0, dom, n_left)
        rk = rng.integers(0, dom, n_right)
        lv = rng.random(n_left) < p_valid
        rv = rng.random(n_right) < p_valid
        left = rel(lk, capacity=max(1, n_left + cap_slack), valid=lv)
        right = rel(rk, capacity=max(1, n_right + cap_slack), valid=rv,
                    val_name="w")
        matches = int(np.sum(lv[:, None] & rv[None, :]
                             & (lk[:, None] == rk[None, :]))
                      if n_left and n_right else 0)
        # straddle the overflow boundary: below, at, and above
        for out_cap in {max(1, matches - 1), max(1, matches),
                        matches + 4}:
            assert_bit_identical(("random", seed, out_cap), left, right,
                                 out_cap)

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 200), k=st.integers(1, 32),
           seed=st.integers(0, 999))
    def test_partition_order_random(n, k, seed):
        rng = np.random.default_rng(seed)
        bucket = jnp.asarray(rng.integers(0, k, n), jnp.int32)
        order = fj.partition_order(bucket, k)
        if order is None:
            return
        want = jnp.argsort(bucket, stable=True)
        assert np.array_equal(np.asarray(order), np.asarray(want))
