"""Pass 2 — the jaxpr audit: every executor lowering traces clean, and
seeded dtype/donation/cache defects are each caught.  Everything here
is trace-only (abstract values, no joins execute)."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (audit_donation, audit_jit_cache,
                            audit_lowerings, audit_traced)
from repro.analysis.jaxpr_audit import _chain_fixture, _key_leaf_indices
from repro.core import SimGrid, chain_edge_inputs
from repro.core.relation import Relation


def test_all_lowerings_audit_clean():
    """Every traced lowering — one-round chain/query, cascade (staged
    and fused+overlapped), the map-side cascade over a real partitioned
    store, and the jitted wrapper with donation (both variants) —
    audits with zero findings."""
    reports = audit_lowerings()
    assert len(reports) == 9
    bad = [r.summary() for r in reports if not r.ok]
    assert not bad, "\n".join(bad)
    names = {r.target for r in reports}
    assert "jaxpr/mapside_cascade_chain" in names
    assert "jaxpr/jit_cache_key" in names
    assert "jaxpr/one_round_query[fused,overlap]" in names
    assert "jaxpr/cascade_query[fused,overlap]" in names
    assert "jaxpr/jit_execute_chain[fused,overlap]" in names
    # Sanity: the audit actually walked the programs.
    assert all(r.metrics.get("n_eqns", 0) > 100 for r in reports
               if r.target != "jaxpr/jit_cache_key")


def test_key_leaf_indices_match_flatten_order():
    """Key columns are located structurally (Relation flattens to
    sorted columns + valid with names only in the treedef)."""
    rel = Relation.from_arrays(b=jnp.ones(4, jnp.int32),
                               a=jnp.ones(4, jnp.int32),
                               v=jnp.ones(4, jnp.float32))
    # flatten order: a, b, v, valid -> key leaves a (0) and b (1).
    assert _key_leaf_indices([rel]) == [0, 1]
    leaves = jax.tree_util.tree_leaves([rel])
    assert len(leaves) == 4


def test_seeded_float_count_accum_caught():
    """Summing int counts through float32 loses exactness above 2^24;
    the audit flags the conversion-then-sum pattern."""
    query, edges, caps = _chain_fixture(3)
    rels = chain_edge_inputs(query, edges, (2, 2))

    def bad(rs):
        c = rs[0].col(query.attrs[0])
        return jnp.sum(c.astype(jnp.float32))

    closed = jax.make_jaxpr(bad)(rels)
    rep = audit_traced(closed, rels, "seeded/float_accum")
    assert "FLOAT_COUNT_ACCUM" in rep.codes
    assert rep.ok  # a warning, not an error


def test_seeded_donation_violation_caught():
    """Returning a donated buffer unchanged is a use-after-donate."""
    f = jax.jit(lambda x: (x, x + 1), donate_argnums=(0,))
    traced = f.trace(jnp.zeros((8,), jnp.int32))
    rep = audit_donation(traced, 1, "seeded/donation")
    assert "DONATED_INPUT_RETURNED" in rep.codes
    assert not rep.ok


def test_benign_position_narrowing_not_flagged():
    """argsort permutations and searchsorted positions derive from keys
    but are bounded by the buffer size — narrowing them is deliberate
    and must not be confused with narrowing the keys themselves."""
    query, edges, caps = _chain_fixture(3)
    rels = chain_edge_inputs(query, edges, (2, 2))

    def positions(rs):
        col = rs[0].col(query.attrs[0]).ravel()
        order = jnp.argsort(col)
        srt = col[order]
        pos = jnp.searchsorted(srt, srt).astype(jnp.int32)
        return order.astype(jnp.int32) + pos

    closed = jax.make_jaxpr(positions)(rels)
    rep = audit_traced(closed, rels, "benign/positions")
    assert "KEY_DTYPE_NARROWED" not in rep.codes


def test_jit_cache_key_coverage():
    rep = audit_jit_cache()
    assert rep.ok, rep.summary()


def test_x64_verifier_subprocess():
    """Acceptance under 64-bit keys: seeded int64→int32 key narrowing
    caught, x64-minted certificates verify, int32-recorded ones are
    stale (subprocess: x64 must be set before JAX arrays exist)."""
    out = subprocess.run(
        [sys.executable, "tests/_verifier_x64_check.py"],
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
