"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per instructions: sweep shapes/dtypes, assert_allclose against ref.py.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hash_partition import hash_histogram, partition_offsets
from repro.kernels.segment_sum import segment_sum


class TestSegmentSum:
    @pytest.mark.parametrize("n,num_segments", [(128, 16), (1000, 64),
                                                (4096, 512), (300, 700)])
    @pytest.mark.parametrize("sorted_ids", [True, False])
    def test_matches_ref(self, n, num_segments, sorted_ids):
        rng = np.random.default_rng(n + num_segments)
        ids = rng.integers(0, num_segments, n).astype(np.int32)
        if sorted_ids:
            ids = np.sort(ids)
        vals = rng.normal(size=n).astype(np.float32)
        got = segment_sum(jnp.array(vals), jnp.array(ids), num_segments,
                          interpret=True, seg_tile=128, block=256)
        want = ref.segment_sum(jnp.array(vals), jnp.array(ids), num_segments)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_out_of_range_dropped(self):
        ids = jnp.array([-1, 0, 1, 5, 99], jnp.int32)
        vals = jnp.ones(5, jnp.float32)
        got = segment_sum(vals, ids, 4, interpret=True, seg_tile=128, block=128)
        np.testing.assert_allclose(np.asarray(got), [1, 1, 0, 0])


class TestHashHistogram:
    @pytest.mark.parametrize("n,k", [(256, 4), (1024, 16), (777, 130), (64, 3)])
    @pytest.mark.parametrize("salt", [0, 1])
    def test_matches_ref(self, n, k, salt):
        rng = np.random.default_rng(n * k + salt)
        keys = rng.integers(0, 1 << 30, n).astype(np.int32)
        valid = rng.random(n) < 0.8
        block = 256
        got = hash_histogram(jnp.array(keys), jnp.array(valid), k, salt=salt,
                             block=block, interpret=True)
        pad = -n % min(block, max(128, 1 << (n - 1).bit_length()))
        want = ref.masked_hash_histogram(
            jnp.pad(jnp.array(keys), (0, pad)),
            jnp.pad(jnp.array(valid), (0, pad)), k, salt=salt,
            block=min(block, max(128, 1 << (n - 1).bit_length())))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # Totals: every valid key lands in exactly one bucket.
        assert int(np.asarray(got).sum()) == int(valid.sum())

    def test_partition_offsets_disjoint(self):
        rng = np.random.default_rng(0)
        keys = rng.integers(0, 1 << 30, 512).astype(np.int32)
        valid = jnp.ones(512, bool)
        hist = hash_histogram(jnp.array(keys), valid, 8, block=128,
                              interpret=True)
        offs = partition_offsets(hist)
        h = np.asarray(hist)
        o = np.asarray(offs)
        # Runs [offset, offset+count) must tile [0, total) without overlap.
        runs = sorted((int(o[i, j]), int(o[i, j] + h[i, j]))
                      for i in range(h.shape[0]) for j in range(h.shape[1]))
        pos = 0
        for lo, hi in runs:
            assert lo == pos
            pos = hi
        assert pos == int(h.sum())


@pytest.mark.slow
class TestFlashAttention:
    # Seed-state note: these 21 cases (plus the MoE dispatch test) were
    # the 40 always-red failures — jax API drift (TPUCompilerParams /
    # shard_map), fixed by repro.compat.  Kept behind the ``slow``
    # marker: they dominate suite wall time and guard kernels, not the
    # join engine.
    @pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
        (1, 4, 4, 128, 128, 64),     # MHA square
        (2, 8, 2, 64, 64, 64),       # GQA
        (1, 4, 1, 32, 32, 128),      # MQA, ragged block
        (1, 8, 2, 1, 256, 64),       # single-token decode vs KV cache
        (1, 4, 2, 17, 40, 64),       # non-pow2 shapes exercise padding
    ])
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, hq, hkv, sq, skv, d, causal, dtype):
        rng = np.random.default_rng(hash((b, hq, sq, skv, causal)) % (1 << 31))
        q = jnp.array(rng.normal(size=(b, hq, sq, d)), dtype)
        k = jnp.array(rng.normal(size=(b, hkv, skv, d)), dtype)
        v = jnp.array(rng.normal(size=(b, hkv, skv, d)), dtype)
        got = flash_attention(q, k, v, causal=causal, interpret=True,
                              block_q=64, block_kv=128)
        want = ref.attention(q, k, v, causal=causal)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_decode_equals_full_last_row(self):
        """Decoding one token against a cache == last row of full attention."""
        rng = np.random.default_rng(7)
        d, h, s = 64, 4, 96
        q = jnp.array(rng.normal(size=(1, h, s, d)), jnp.float32)
        k = jnp.array(rng.normal(size=(1, h, s, d)), jnp.float32)
        v = jnp.array(rng.normal(size=(1, h, s, d)), jnp.float32)
        full = flash_attention(q, k, v, causal=True, interpret=True)
        one = flash_attention(q[:, :, -1:], k, v, causal=True, interpret=True)
        np.testing.assert_allclose(np.asarray(one[0, :, 0]),
                                   np.asarray(full[0, :, -1]),
                                   rtol=1e-5, atol=1e-5)
