"""MoE dispatch strategies on a real multi-device mesh (subprocess: the
main pytest process must keep its single CPU device)."""

import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow


def test_dispatch_strategies_match_reference():
    out = subprocess.run(
        [sys.executable, "tests/_moe_dist_check.py"],
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
