"""The chunked (overlapped) hop shuffle is invisible to results.

``overlap_chunks=C`` splits each hop's send side into C row blocks so
block b+1's all-to-all can overlap block b's local join.  The schedule
must change *nothing observable*: same output tuples, same overflow
flag, bit-equal stats (the Shares/cascade accounting is per-tuple, and
chunking moves the same tuples).  These tests pin that across every
executor entry point on SimGrid; ``tests/_query_shard_check.py`` pins
the same equality (plus the collective structure of the lowering) on a
real multi-device ShardGrid.

Also pins the cost-model overlap envelope: ``hop_time_overlapped`` at
C=1 equals the staged time, never exceeds it, and
``overlap_hidden_fraction`` handles the degenerate zero-shuffle case.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (ChainCaps, ChainQuery, JoinQuery, Relation, SimGrid,
                        cascade_chain, chain_edge_inputs, execute_query,
                        query_table_inputs, two_way_join)
from repro.core.cost_model import (hop_time_overlapped, hop_time_staged,
                                   overlap_hidden_fraction)
from repro.core.shuffle import concat_rows, split_rows

CHUNK_COUNTS = (2, 3, 5)


def edges(rng, dom, m):
    return (rng.integers(0, dom, m).astype(np.int32),
            rng.integers(0, dom, m).astype(np.int32))


def run_and_snapshot(fn, chunks):
    out, st, ovf = fn(chunks)
    return (out.to_tuple_set(), int(np.sum(np.asarray(out.valid))),
            bool(ovf), {k: np.asarray(v) for k, v in st.items()})


def assert_overlap_invisible(fn, *, expect_overflow=False):
    """fn(chunks) -> (out, stats, ovf); every chunking must match C=1."""
    base_set, base_n, base_ovf, base_st = run_and_snapshot(fn, 1)
    assert base_ovf == expect_overflow
    for c in CHUNK_COUNTS:
        got_set, got_n, got_ovf, got_st = run_and_snapshot(fn, c)
        assert got_ovf == base_ovf, c
        assert sorted(got_st) == sorted(base_st), c
        for k in base_st:
            assert np.array_equal(got_st[k], base_st[k]), (c, k)
        # Under overflow only the flag and the accounting are
        # schedule-invariant: truncation hits *pre-filter* matches
        # (cycle-closing predicates filter after the capacity cut), so
        # the schedules can retain different survivor subsets.
        if not expect_overflow:
            assert got_n == base_n, c
            assert got_set == base_set, c


def test_two_way_join_overlap():
    rng = np.random.default_rng(0)
    grid = SimGrid((4,))
    q2 = ChainQuery.chain(2)
    left, right = chain_edge_inputs(
        q2, [edges(rng, 12, 40), edges(rng, 12, 40)], (4,))

    def fn(chunks):
        return two_way_join(grid, left, right, "b", "b",
                            recv_capacity=256, out_capacity=2048,
                            overlap_chunks=chunks)

    assert_overlap_invisible(fn)


def test_cascade_chain_pushdown_overlap():
    rng = np.random.default_rng(1)
    query = ChainQuery.chain(3, aggregate=True)
    rels = chain_edge_inputs(query, [edges(rng, 16, 48) for _ in range(3)],
                             (4,))
    grid = SimGrid((4,))
    caps = ChainCaps(recv=512, mid=2048, out=4096, local=1024, agg=1024)

    def fn(chunks):
        return cascade_chain(grid, query, rels, caps=caps, pushdown=True,
                             measure_skew=True, overlap_chunks=chunks)

    assert_overlap_invisible(fn)


@pytest.mark.parametrize("strategy,shape", [("one_round", (2, 2, 2)),
                                            ("cascade", (4,))])
def test_triangle_overlap(strategy, shape):
    rng = np.random.default_rng(2)
    query = JoinQuery.triangle()
    rels = query_table_inputs(query, [edges(rng, 14, 48)] * 3, shape)
    grid = SimGrid(shape)
    caps = ChainCaps(recv=512, mid=4096, out=8192, local=1024)

    def fn(chunks):
        return execute_query(grid, query, rels, strategy=strategy,
                             caps=caps, overlap_chunks=chunks)

    assert_overlap_invisible(fn)


@pytest.mark.parametrize("strategy,shape", [("one_round", (2, 2, 2)),
                                            ("cascade", (4,))])
def test_triangle_overlap_tiny_out_overflow(strategy, shape):
    # out=8 is far below the triangle count: the shared final
    # compaction must raise the same overflow under every chunking.
    rng = np.random.default_rng(3)
    query = JoinQuery.triangle()
    rels = query_table_inputs(query, [edges(rng, 8, 64)] * 3, shape)
    grid = SimGrid(shape)
    caps = ChainCaps(recv=512, mid=4096, out=8, local=1024)

    def fn(chunks):
        return execute_query(grid, query, rels, strategy=strategy,
                             caps=caps, overlap_chunks=chunks)

    assert_overlap_invisible(fn, expect_overflow=True)


def test_star_one_round_overlap():
    rng = np.random.default_rng(4)
    query = JoinQuery.star(3)
    rels = query_table_inputs(query, [edges(rng, 10, 40)] * 3, (4,))
    grid = SimGrid((4,))
    caps = ChainCaps(recv=512, mid=4096, out=8192, local=1024)

    def fn(chunks):
        return execute_query(grid, query, rels, strategy="one_round",
                             caps=caps, overlap_chunks=chunks)

    assert_overlap_invisible(fn)


def test_split_concat_rows_partition_rows_exactly():
    rng = np.random.default_rng(5)
    cols = {"b": jnp.asarray(rng.integers(0, 9, 37), jnp.int32),
            "v": jnp.asarray(rng.random(37), jnp.float32)}
    valid = jnp.asarray(rng.random(37) < 0.6)
    rel = Relation(cols, valid)
    for chunks in (1, 2, 3, 5, 37, 100):
        parts = split_rows(rel, chunks)
        assert len(parts) == min(max(1, chunks), rel.capacity)
        assert sum(p.capacity for p in parts) == rel.capacity
        assert sum(int(jnp.sum(p.valid)) for p in parts) \
            == int(jnp.sum(rel.valid))
        merged = concat_rows(parts)
        assert np.array_equal(np.asarray(merged.valid), np.asarray(valid))
        for n in cols:
            assert np.array_equal(np.asarray(merged.cols[n]),
                                  np.asarray(cols[n]))


def test_hop_time_model():
    # C=1 degenerates to the staged time exactly
    assert hop_time_overlapped(3.0, 5.0, 1) == hop_time_staged(3.0, 5.0)
    # never exceeds staged; non-increasing in C when both phases run
    prev = hop_time_staged(4.0, 6.0)
    for c in (1, 2, 3, 4, 8, 16):
        t = hop_time_overlapped(4.0, 6.0, c)
        assert t <= prev + 1e-12, c
        prev = t
    # C→∞ limit: the longer phase
    assert abs(hop_time_overlapped(4.0, 6.0, 10 ** 6) - 6.0) < 1e-3
    # fully compute-bound hiding: fraction → 1 as C grows
    frac = overlap_hidden_fraction(hop_time_staged(4.0, 6.0),
                                   hop_time_overlapped(4.0, 6.0, 8),
                                   4.0)
    assert 0.8 < frac <= 1.0
    # degenerate zero-shuffle hop
    assert overlap_hidden_fraction(5.0, 5.0, 0.0) == 0.0
    assert overlap_hidden_fraction(5.0, 5.0, -1.0) == 0.0
