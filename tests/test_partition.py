"""Partitioned storage + map-side joins (ISSUE 6).

* partition_relation / sort_rows layout invariants and flat round-trip
* save_partitioned / load_partitioned: bit-identical round-trip
  (deterministic sweep + hypothesis property when available), manifest
  spec recovery, CRC corruption detection
* atomic checkpoint replace: interrupted-swap recovery, ``.old``
  leftovers never break latest_step / CheckpointManager gc
* co-partitioning proofs: positive and negative cases, chain
  certificates (full / partial / none)
* planner: MS,NJ candidate, broadcast-vs-shuffle-vs-mapside mode
  crossover, bit-for-bit PR-5 plans when no certificate is given
* executor: mapside == cascade result equivalence (mixed modes and the
  all-proven ``place_output`` zero-shuffle path), measured == analytic
  per-hop shuffled/placed counts
* guards: all-pairs int32 pair-index overflow raises; x64 and ShardGrid
  subprocess runs
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (CheckpointManager, latest_step,
                              load_partition_spec, load_partitioned, restore,
                              save, save_partitioned)
from repro.core import (ChainQuery, PartitionSpec, PartitionedRelation,
                        SimGrid, chain_mapside_modes, chain_mapside_placed,
                        chain_mapside_shuffles, chain_partitioning,
                        chain_stats_exact, co_partitioned, cost_chain_mapside,
                        default_chain_caps, edge_relation, execute_chain,
                        local_join_allpairs, partition_relation, plan_chain,
                        scatter_to_grid, sort_rows)
from repro.core.cost_model import ChainPartitioning
from repro.core.hashing import bucket_hash
from repro.core.relation import Relation


def _edges(rng, m, dom):
    return rng.integers(0, dom, m), rng.integers(0, dom, m)


def _chain_inputs(rng, query, m, dom):
    n = query.n_relations
    edges = [_edges(rng, m, dom) for _ in range(n)]
    flat = [edge_relation(s, d, names=query.schema(j))
            for j, (s, d) in enumerate(edges)]
    return edges, flat


def _partition_chain(query, flat, P, salt=0):
    prels = []
    for j, rel in enumerate(flat):
        key = query.attrs[1] if j == 0 else query.attrs[j]
        pr, ovf = partition_relation(rel, key, P, salt=salt)
        assert not bool(ovf)
        prels.append(pr)
    return prels


def _tuples(rel):
    cols = sorted(rel.cols)
    arrs = [np.asarray(rel.cols[c]).reshape(-1) for c in cols]
    valid = np.asarray(rel.valid).reshape(-1)
    return sorted(tuple(a[i] for a in arrs) for i in np.nonzero(valid)[0])


# ---------------------------------------------------------------------------
# Partition layout
# ---------------------------------------------------------------------------

class TestPartitionLayout:
    def test_partition_buckets_and_sort(self):
        rng = np.random.default_rng(0)
        rel = edge_relation(*_edges(rng, 300, 50))
        pr, ovf = partition_relation(rel, "a", 8, salt=2)
        assert not bool(ovf)
        assert pr.num_partitions == 8 and pr.part_capacity == rel.capacity
        assert pr.spec == PartitionSpec(key="a", num_partitions=8, salt=2,
                                        key_dtype="int32")
        for p in range(8):
            valid = np.asarray(pr.parts.valid[p])
            keys = np.asarray(pr.parts.cols["a"][p])[valid]
            assert (np.asarray(bucket_hash(jnp.asarray(keys), 8, salt=2))
                    == p).all(), "tuple in the wrong partition"
            assert (np.diff(keys) >= 0).all(), "partition not key-sorted"
            # sorted-rows contract: valid rows first
            assert not valid[np.argmin(valid):].any() or valid.all()
        assert int(pr.count()) == int(rel.count())

    def test_to_flat_preserves_tuples(self):
        rng = np.random.default_rng(1)
        rel = edge_relation(*_edges(rng, 123, 37))
        pr, _ = partition_relation(rel, "b", 4)
        assert _tuples(pr.to_flat()) == _tuples(rel)

    def test_sort_rows_contract(self):
        rel = Relation.from_arrays(
            16, a=jnp.asarray(np.arange(9, -1, -1), jnp.int32),
            v=jnp.arange(10, dtype=jnp.float32))
        srt = sort_rows(rel, "a")
        valid = np.asarray(srt.valid)
        keys = np.asarray(srt.col("a"))[valid]
        assert valid[:10].all() and not valid[10:].any()
        assert (np.diff(keys) >= 0).all()

    def test_part_capacity_overflow_flag(self):
        rel = edge_relation(np.zeros(64, np.int32), np.zeros(64, np.int32))
        _, ovf = partition_relation(rel, "a", 4, part_capacity=8)
        assert bool(ovf), "all keys in one bucket must overflow cap 8"


# ---------------------------------------------------------------------------
# Persistence
# ---------------------------------------------------------------------------

class TestPartitionedStore:
    def _roundtrip(self, tmp_path, seed, m, dom, P, salt):
        rng = np.random.default_rng(seed)
        rel = edge_relation(*_edges(rng, m, dom))
        pr, _ = partition_relation(rel, "a", P, salt=salt)
        save_partitioned(str(tmp_path), f"r{seed}", pr)
        back = load_partitioned(str(tmp_path), f"r{seed}")
        assert back.spec == pr.spec
        for c in pr.parts.cols:
            assert (np.asarray(back.parts.cols[c])
                    == np.asarray(pr.parts.cols[c])).all()
            assert back.parts.cols[c].dtype == pr.parts.cols[c].dtype
        assert (np.asarray(back.parts.valid)
                == np.asarray(pr.parts.valid)).all()

    def test_roundtrip_sweep(self, tmp_path):
        for seed, m, dom, P, salt in [(0, 50, 11, 2, 0), (1, 200, 64, 8, 3),
                                      (2, 17, 5, 16, 1), (3, 333, 1000, 5, 7)]:
            self._roundtrip(tmp_path, seed, m, dom, P, salt)

    def test_roundtrip_property(self, tmp_path):
        hyp = pytest.importorskip(
            "hypothesis", reason="hypothesis not installed; the "
            "deterministic sweep above still covers the round-trip")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=15, deadline=None)
        @given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 128),
               dom=st.integers(1, 256), P=st.integers(1, 12))
        def prop(seed, m, dom, P):
            self._roundtrip(tmp_path, seed, m, dom, P, salt=seed % 5)

        prop()

    def test_spec_only_read(self, tmp_path):
        rng = np.random.default_rng(5)
        pr, _ = partition_relation(edge_relation(*_edges(rng, 40, 9)), "b", 4,
                                   salt=1)
        save_partitioned(str(tmp_path), "edges", pr)
        spec = load_partition_spec(str(tmp_path), "edges")
        assert spec == PartitionSpec(key="b", num_partitions=4, salt=1,
                                     key_dtype="int32")
        assert load_partition_spec(str(tmp_path), "absent") is None

    def test_corruption_detected(self, tmp_path):
        rng = np.random.default_rng(6)
        pr, _ = partition_relation(edge_relation(*_edges(rng, 64, 16)), "a", 2)
        path = save_partitioned(str(tmp_path), "edges", pr)
        victim = os.path.join(path, "part_00001.npz")
        data = dict(np.load(victim))
        data["a"] = data["a"].copy()
        data["a"][0] ^= 1                      # silent bit flip in a key
        np.savez(victim, **data)
        with pytest.raises(IOError, match="corrupt"):
            load_partitioned(str(tmp_path), "edges")

    def test_overwrite_replaces_atomically(self, tmp_path):
        rng = np.random.default_rng(7)
        rel = edge_relation(*_edges(rng, 64, 16))
        pr_a, _ = partition_relation(rel, "a", 4)
        pr_b, _ = partition_relation(rel, "b", 8, salt=2)
        save_partitioned(str(tmp_path), "edges", pr_a)
        save_partitioned(str(tmp_path), "edges", pr_b)
        spec = load_partition_spec(str(tmp_path), "edges")
        assert spec.key == "b" and spec.num_partitions == 8
        assert not os.path.exists(os.path.join(str(tmp_path), "edges.old"))

    def test_interrupted_swap_recovers(self, tmp_path):
        rng = np.random.default_rng(8)
        pr, _ = partition_relation(edge_relation(*_edges(rng, 64, 16)), "a", 4)
        save_partitioned(str(tmp_path), "edges", pr)
        # Simulate a crash between the two renames: old moved aside,
        # new never moved in.
        os.rename(os.path.join(str(tmp_path), "edges"),
                  os.path.join(str(tmp_path), "edges.old"))
        back = load_partitioned(str(tmp_path), "edges")
        assert back.spec == pr.spec


class TestAtomicCheckpointReplace:
    def test_resave_step_keeps_a_valid_checkpoint(self, tmp_path):
        tree = {"w": jnp.arange(8, dtype=jnp.float32)}
        save(str(tmp_path), 3, tree)
        save(str(tmp_path), 3, jax.tree.map(lambda a: a + 1, tree))
        got, _ = restore(str(tmp_path), 3, tree)
        assert (np.asarray(got["w"]) == np.arange(8) + 1).all()
        assert not os.path.exists(os.path.join(str(tmp_path), "step_3.old"))

    def test_interrupted_swap_restores_old(self, tmp_path):
        tree = {"w": jnp.arange(4, dtype=jnp.float32)}
        save(str(tmp_path), 1, tree)
        os.rename(os.path.join(str(tmp_path), "step_1"),
                  os.path.join(str(tmp_path), "step_1.old"))
        assert latest_step(str(tmp_path)) == 1   # recovery ran
        got, _ = restore(str(tmp_path), 1, tree)
        assert (np.asarray(got["w"]) == np.arange(4)).all()

    def test_gc_ignores_old_leftovers(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=2, async_write=False)
        tree = {"w": jnp.zeros(2)}
        os.makedirs(os.path.join(str(tmp_path), "step_0.old"))
        for s in range(4):
            mgr.save(s, tree, block=True)   # _gc must not crash on .old
        assert latest_step(str(tmp_path)) == 3


# ---------------------------------------------------------------------------
# Co-partitioning proofs
# ---------------------------------------------------------------------------

class TestCoPartitioningProof:
    A4 = PartitionSpec(key="a", num_partitions=4, salt=0)

    def test_positive(self):
        assert co_partitioned(self.A4, self.A4)
        b4 = PartitionSpec(key="b", num_partitions=4, salt=0)
        assert co_partitioned(self.A4, b4, key_a="a", key_b="b")

    @pytest.mark.parametrize("other,kwargs", [
        (None, {}),
        (PartitionSpec(key="a", num_partitions=8, salt=0), {}),   # P differs
        (PartitionSpec(key="a", num_partitions=4, salt=1), {}),   # salt differs
        (PartitionSpec(key="a", num_partitions=4, salt=0,
                       sort_order="none"), {}),                   # unsorted
        (PartitionSpec(key="b", num_partitions=4, salt=0),
         {"key_b": "a"}),                                         # wrong attr
    ])
    def test_negative(self, other, kwargs):
        assert not co_partitioned(self.A4, other, **kwargs)

    def test_chain_certificate_full(self):
        query = ChainQuery.chain(4)
        specs = [PartitionSpec(key=query.attrs[1], num_partitions=8)] + [
            PartitionSpec(key=query.attrs[j], num_partitions=8)
            for j in range(1, 4)]
        part = chain_partitioning(query, specs)
        assert part == ChainPartitioning(num_partitions=8, salt=0,
                                         right_proven=(True, True, True),
                                         left0_proven=True)

    def test_chain_certificate_partial_and_salt_mismatch(self):
        query = ChainQuery.chain(4)
        specs = [None,
                 PartitionSpec(key=query.attrs[1], num_partitions=8, salt=2),
                 PartitionSpec(key=query.attrs[2], num_partitions=8, salt=5),
                 PartitionSpec(key="wrong", num_partitions=8, salt=2)]
        part = chain_partitioning(query, specs)
        # canonical (P=8, salt=2) from the first provable spec; the
        # salt-5 and wrong-key specs stay unproven.
        assert part.right_proven == (True, False, False)
        assert not part.left0_proven and part.salt == 2

    def test_chain_certificate_none(self):
        query = ChainQuery.chain(3)
        assert chain_partitioning(query, [None, None, None]) is None
        with pytest.raises(ValueError):
            chain_partitioning(query, [None, None])


# ---------------------------------------------------------------------------
# Planner: the MS,NJ candidate and mode crossover
# ---------------------------------------------------------------------------

class TestMapsidePlanning:
    def _stats(self, rng, n=4, m=150, dom=300):
        return chain_stats_exact([_edges(rng, m, dom) for _ in range(n)])

    def test_mode_crossover(self):
        part = ChainPartitioning(num_partitions=4, salt=0,
                                 right_proven=(True, False, False),
                                 left0_proven=True)
        sizes = [100.0, 100.0, 10.0, 1000.0]
        prefix = [50.0, 30.0, 20.0]
        modes = chain_mapside_modes(sizes, prefix, part)
        # hop1 proven+left-on-key: free map-side beats everything;
        # hop2 unproven, tiny right: broadcast 4·10 < shuffle 50+10;
        # hop3 unproven, huge right: shuffle 30+1000 < broadcast 4000.
        assert modes == ("mapside", "broadcast", "shuffle")
        # a threshold below the hop2 right size disables its broadcast
        modes_t = chain_mapside_modes(sizes, prefix, part,
                                      broadcast_threshold=5.0)
        assert modes_t == ("mapside", "shuffle", "shuffle")

    def test_shuffle_and_placed_vectors(self):
        part = ChainPartitioning(num_partitions=4, salt=0,
                                 right_proven=(True, True, True),
                                 left0_proven=True)
        sizes = [100.0] * 4
        prefix = [40.0, 30.0, 20.0]
        modes = ("mapside",) * 3
        assert chain_mapside_shuffles(sizes, prefix, part, modes) == \
            (0.0, 40.0, 30.0)
        # place_output moves each intermediate at birth instead
        assert chain_mapside_shuffles(sizes, prefix, part, modes,
                                      place_output=True) == (0.0, 0.0, 0.0)
        assert chain_mapside_placed(sizes, prefix, part, modes) == \
            (40.0, 30.0, 0.0)
        # invariant: total movement identical either way
        assert sum(chain_mapside_shuffles(sizes, prefix, part, modes)) == \
            sum(chain_mapside_shuffles(sizes, prefix, part, modes,
                                       place_output=True)) + \
            sum(chain_mapside_placed(sizes, prefix, part, modes))
        reads = sum(sizes) + prefix[0] + prefix[1]
        assert cost_chain_mapside(sizes, prefix, part, modes) == \
            reads + 70.0

    def test_plan_picks_mapside_when_proven(self):
        rng = np.random.default_rng(10)
        stats = self._stats(rng)
        part = ChainPartitioning(num_partitions=8, salt=0,
                                 right_proven=(True, True, True),
                                 left0_proven=True)
        plan = plan_chain(stats, k=8, aggregate=False, partitioning=part)
        assert plan.algorithm == "MS,4J" and plan.strategy == "mapside"
        assert plan.grid_shape == (8,)
        assert plan.hop_modes == ("mapside",) * 3
        assert plan.partitioning == part
        assert plan.costs["MS,4J"] < plan.costs["3,4J"]

    def test_no_certificate_keeps_plans_bitforbit(self):
        rng = np.random.default_rng(11)
        stats = self._stats(rng)
        for aggregate in (False, True):
            assert plan_chain(stats, k=8, aggregate=aggregate) == \
                plan_chain(stats, k=8, aggregate=aggregate, partitioning=None)
            plan = plan_chain(stats, k=8, aggregate=aggregate)
            assert plan.partitioning is None and plan.hop_modes is None
            assert "MS,4J" not in "".join(plan.costs)


# ---------------------------------------------------------------------------
# Executor: map-side cascade == shuffle cascade
# ---------------------------------------------------------------------------

class TestMapsideExecution:
    P = 4

    def _setup(self, seed, n, m, dom):
        rng = np.random.default_rng(seed)
        query = ChainQuery.chain(n)
        edges, flat = _chain_inputs(rng, query, m, dom)
        stats = chain_stats_exact(edges)
        caps = default_chain_caps(stats, (self.P,), slack=8)
        grid = SimGrid((self.P,))
        ref, _, ovf = execute_chain(
            grid, query, [scatter_to_grid(r, (self.P,)) for r in flat],
            strategy="cascade", caps=caps)
        assert not bool(ovf)
        return query, flat, stats, caps, grid, _tuples(ref)

    def test_all_proven_place_output_zero_shuffle(self):
        query, flat, stats, caps, grid, want = self._setup(20, 4, 150, 300)
        prels = _partition_chain(query, flat, self.P)
        part = chain_partitioning(query, [pr.spec for pr in prels])
        plan = plan_chain(stats, k=self.P, aggregate=False, partitioning=part)
        assert plan.hop_modes == ("mapside",) * 3
        out, st, ovf = execute_chain(
            grid, query, prels, strategy="mapside", caps=caps,
            partitioning=part, hop_modes=plan.hop_modes, place_output=True)
        assert not bool(ovf)
        assert _tuples(out) == want
        shuffled = tuple(float(x) for x in np.asarray(st["hop_shuffled"]))
        placed = tuple(float(x) for x in np.asarray(st["hop_placed"]))
        assert shuffled == (0.0, 0.0, 0.0)
        assert placed == chain_mapside_placed(
            stats.sizes, stats.prefix_joins, part, plan.hop_modes)
        assert float(st["total"]) == float(st["read"]) + sum(placed)

    def test_mixed_modes_match_cascade_and_analytic(self):
        query, flat, stats, caps, grid, want = self._setup(21, 4, 120, 24)
        prels = _partition_chain(query, flat, self.P)
        # only relation 2 stored partitioned; others arrive scattered
        specs = [None, None, prels[2].spec, None]
        part = chain_partitioning(query, specs)
        plan = plan_chain(stats, k=self.P, aggregate=False, partitioning=part)
        rels = [scatter_to_grid(r, (self.P,)) for r in flat]
        rels[2] = prels[2]
        out, st, ovf = execute_chain(
            grid, query, rels, strategy="mapside", caps=caps,
            partitioning=part, hop_modes=plan.hop_modes)
        assert not bool(ovf)
        assert _tuples(out) == want
        measured = tuple(float(x) for x in np.asarray(st["hop_shuffled"]))
        assert measured == chain_mapside_shuffles(
            stats.sizes, stats.prefix_joins, part, plan.hop_modes)

    def test_aggregated_mapside_matches_cascade(self):
        rng = np.random.default_rng(22)
        query = ChainQuery.chain(3, aggregate=True)
        edges, flat = _chain_inputs(rng, query, 100, 40)
        stats = chain_stats_exact(edges)
        caps = default_chain_caps(stats, (self.P,), slack=8)
        grid = SimGrid((self.P,))
        prels = _partition_chain(query, flat, self.P)
        part = chain_partitioning(query, [pr.spec for pr in prels])
        plan = plan_chain(stats, k=self.P, aggregate=True, partitioning=part)
        assert plan.algorithm.startswith(("MS,", "1,", "2,"))
        out, st, ovf = execute_chain(
            grid, query, prels, strategy="mapside", caps=caps,
            partitioning=part, hop_modes=("mapside", "mapside"))
        assert not bool(ovf)
        ref, _, _ = execute_chain(
            grid, query, [scatter_to_grid(r, (self.P,)) for r in flat],
            strategy="cascade", caps=caps)
        assert _tuples(out) == _tuples(ref)

    def test_unproven_mapside_mode_rejected(self):
        query, flat, stats, caps, grid, _ = self._setup(23, 3, 40, 10)
        part = ChainPartitioning(num_partitions=self.P, salt=0,
                                 right_proven=(False, True),
                                 left0_proven=False)
        with pytest.raises(ValueError, match="not proven"):
            execute_chain(grid, query,
                          [scatter_to_grid(r, (self.P,)) for r in flat],
                          strategy="mapside", caps=caps, partitioning=part,
                          hop_modes=("mapside", "mapside"))

    def test_mapside_needs_certificate(self):
        query, flat, stats, caps, grid, _ = self._setup(24, 3, 40, 10)
        with pytest.raises(ValueError, match="partitioning"):
            execute_chain(grid, query,
                          [scatter_to_grid(r, (self.P,)) for r in flat],
                          strategy="mapside", caps=caps)


# ---------------------------------------------------------------------------
# Guards + subprocess acceptance runs
# ---------------------------------------------------------------------------

class TestGuards:
    def test_allpairs_pair_index_overflow_raises(self):
        big = Relation.from_arrays(
            50_000, a=jnp.zeros(50_000, jnp.int32),
            v=jnp.zeros(50_000, jnp.float32))
        with pytest.raises(ValueError, match="overflows int32"):
            local_join_allpairs(big, big.rename({"v": "w"}), "a", "a",
                                out_capacity=64)


def test_mapside_on_shard_grid_subprocess():
    """Acceptance: the fully proven map-side cascade executes on a real
    8-device ShardGrid with zero per-hop shuffled tuples (subprocess
    keeps pytest single-device)."""
    out = subprocess.run(
        [sys.executable, "tests/_mapside_shard_check.py"],
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


def test_x64_keys_subprocess():
    """Acceptance: int64 join keys above 2^32 join correctly under
    jax_enable_x64 (subprocess: the flag must be set before JAX arrays
    exist)."""
    out = subprocess.run(
        [sys.executable, "tests/_x64_check.py"],
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
