"""N-way chain executor tests: the plan-IR → executor path.

* A 4-way chain join via the one-round hypercube, via the cascade, and
  via a brute-force ``local_join`` reference all produce identical
  relations (including the aggregated variant).
* Measured shuffle counts match the extended analytic cost model
  EXACTLY (one-round Shares replication and cascade round charges).
* The N=3 query-API path is bit-identical to the
  ``one_round_three_way`` / ``cascade_three_way`` entry points.
* The planner drives a 4-way query end-to-end.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    ChainCaps, ChainQuery, Relation, SimGrid, cascade_chain,
    cascade_three_way, cascade_three_way_agg, chain_edge_inputs,
    chain_replications, chain_stats_exact, cost_chain_cascade,
    cost_chain_cascade_pushdown, edge_relation, execute_chain,
    one_round_chain, one_round_three_way, plan_chain, scatter_to_grid,
)
from repro.core.local import local_join


def rand_edges(rng, n_nodes, n_edges):
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    return src, dst


def collect_tuples(out: Relation, grid_rank: int, names) -> set:
    flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[grid_rank:]), out)
    got = set()
    for dev in range(flat.valid.shape[0]):
        sub = Relation({k: v[dev] for k, v in flat.cols.items()},
                       flat.valid[dev])
        got |= sub.to_tuple_set(names)
    return got


def collect_agg(out: Relation, grid_rank: int, keys, value="p") -> dict:
    flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[grid_rank:]), out)
    got = {}
    for dev in range(flat.valid.shape[0]):
        sub = Relation({k: v[dev] for k, v in flat.cols.items()},
                       flat.valid[dev])
        d = sub.to_numpy()
        for row in zip(*([d[k] for k in keys] + [d[value]])):
            *ks, p = row
            key = tuple(int(x) for x in ks)
            got[key] = got.get(key, 0.0) + float(p)
    return got


def local_reference(query: ChainQuery, edge_lists, out_capacity=65536):
    """Brute-force reference: one device, a chain of local_joins."""
    acc = None
    for j, (src, dst) in enumerate(edge_lists):
        names = query.schema(j)
        rel = edge_relation(src, dst, names=(names[0], names[1], names[2]))
        if acc is None:
            acc = rel
            continue
        key = query.attrs[j]
        acc, ovf = local_join(acc, rel, key, key, out_capacity)
        assert not bool(ovf), "reference overflow — raise out_capacity"
    return acc


def agg_oracle(query: ChainQuery, reference: Relation) -> dict:
    d = reference.to_numpy()
    keys = (query.attrs[0], query.attrs[-1])
    got = {}
    prod = np.ones_like(d[query.values[0]], dtype=np.float64)
    for v in query.values:
        prod = prod * d[v].astype(np.float64)
    for a, z, p in zip(d[keys[0]], d[keys[1]], prod):
        got[(int(a), int(z))] = got.get((int(a), int(z)), 0.0) + float(p)
    return got


N4_GRID = (2, 2, 2)
CAPS4 = ChainCaps(recv=96, mid=2048, out=8192, local=128, agg=1024, join=8192)


class TestFourWayEquivalence:
    def setup_method(self, method):
        rng = np.random.default_rng(42)
        self.edges = [rand_edges(rng, 9, 28) for _ in range(4)]

    def test_enumeration_all_strategies_agree(self):
        query = ChainQuery.chain(4)
        ref = local_reference(query, self.edges)
        expect = ref.to_tuple_set(query.attrs)
        assert expect, "degenerate test: empty reference join"

        grid3 = SimGrid(N4_GRID)
        rels3 = chain_edge_inputs(query, self.edges, N4_GRID)
        out1, st1, ovf1 = one_round_chain(grid3, query, rels3, caps=CAPS4)
        assert not bool(ovf1)
        assert collect_tuples(out1, 3, query.attrs) == expect

        grid2 = SimGrid((2, 2))
        rels2 = chain_edge_inputs(query, self.edges, (2, 2))
        out2, st2, ovf2 = cascade_chain(grid2, query, rels2, caps=CAPS4)
        assert not bool(ovf2)
        assert collect_tuples(out2, 2, query.attrs) == expect

    def test_aggregated_all_strategies_agree(self):
        query = ChainQuery.chain(4, aggregate=True)
        ref = local_reference(query, self.edges)
        expect = agg_oracle(query, ref)

        grid3 = SimGrid(N4_GRID)
        rels3 = chain_edge_inputs(query, self.edges, N4_GRID)
        out1, _, ovf1 = one_round_chain(grid3, query, rels3, caps=CAPS4)
        assert not bool(ovf1)
        got1 = collect_agg(out1, 3, ("a", "e"))

        grid2 = SimGrid((2, 2))
        rels2 = chain_edge_inputs(query, self.edges, (2, 2))
        out2, _, ovf2 = cascade_chain(grid2, query, rels2, caps=CAPS4,
                                      pushdown=True)
        assert not bool(ovf2)
        got2 = collect_agg(out2, 2, ("a", "e"))

        assert set(got1) == set(got2) == set(expect)
        for k in expect:
            np.testing.assert_allclose(got1[k], expect[k], rtol=1e-5)
            np.testing.assert_allclose(got2[k], expect[k], rtol=1e-5)

    def test_measured_matches_analytic_exactly(self):
        """Acceptance: 4-way measured shuffle == extended cost model."""
        query = ChainQuery.chain(4)
        sizes = tuple(float(len(s)) for s, _ in self.edges)
        stats = chain_stats_exact(self.edges)

        # One round on explicit integer shares (2,2,2): shuffled must be
        # Σ r_j · K/m_j exactly.
        grid3 = SimGrid(N4_GRID)
        rels3 = chain_edge_inputs(query, self.edges, N4_GRID)
        _, st1, ovf = one_round_chain(grid3, query, rels3, caps=CAPS4)
        assert not bool(ovf)
        repl = chain_replications(sizes, N4_GRID)
        analytic_shuffle = sum(r * f for r, f in zip(sizes, repl))
        assert float(st1["read"]) == sum(sizes)
        assert float(st1["shuffled"]) == analytic_shuffle

        # Cascade: total == cost_chain_cascade with EXACT prefix sizes.
        grid2 = SimGrid((2, 2))
        rels2 = chain_edge_inputs(query, self.edges, (2, 2))
        _, st2, ovf2 = cascade_chain(grid2, query, rels2, caps=CAPS4)
        assert not bool(ovf2)
        assert float(st2["total"]) == cost_chain_cascade(
            sizes, stats.prefix_joins)

        # Cascade + pushdown (aggregated): total == the pushdown formula.
        queryA = ChainQuery.chain(4, aggregate=True)
        relsA = chain_edge_inputs(queryA, self.edges, (2, 2))
        _, st3, ovf3 = cascade_chain(grid2, queryA, relsA, caps=CAPS4,
                                     pushdown=True)
        assert not bool(ovf3)
        assert float(st3["total"]) == cost_chain_cascade_pushdown(
            sizes, stats.prefix_joins, stats.prefix_aggs,
            stats.pushdown_joins)

    def test_planner_drives_four_way_end_to_end(self):
        """Acceptance: a 4-way chain runs through the planner on SimGrid."""
        queryA = ChainQuery.chain(4, aggregate=True)
        stats = chain_stats_exact(self.edges)
        plan = plan_chain(stats, k=8, aggregate=True)
        assert plan.algorithm in ("3,4JA", "1,4JA")
        assert plan.strategy in ("cascade_pushdown", "one_round")

        grid_shape = plan.grid_shape if plan.strategy == "one_round" else (2, 2)
        grid = SimGrid(grid_shape)
        rels = chain_edge_inputs(queryA, self.edges, grid_shape)
        out, st, ovf = execute_chain(grid, queryA, rels,
                                     strategy=plan.strategy, caps=CAPS4,
                                     measure_skew=True)
        assert not bool(ovf)
        ref = local_reference(queryA, self.edges)
        expect = agg_oracle(queryA, ref)
        got = collect_agg(out, len(grid_shape), ("a", "e"))
        assert set(got) == set(expect)
        for k in expect:
            np.testing.assert_allclose(got[k], expect[k], rtol=1e-5)
        # Skew diagnostics flowed through the map-phase histogram path.
        assert float(st["max_bucket_load"]) > 0
        assert float(st["max_bucket_load"]) <= float(st["read"])


class TestThreeWayBitIdentical:
    """The query-API N=3 path must equal the paper entry points exactly."""

    def setup_method(self, method):
        rng = np.random.default_rng(4)
        self.src, self.dst = rand_edges(rng, 12, 40)
        shape = (2, 2)
        self.grid = SimGrid(shape)
        self.R = scatter_to_grid(edge_relation(self.src, self.dst,
                                               names=("a", "b", "v")), shape)
        self.S = scatter_to_grid(edge_relation(self.src, self.dst,
                                               names=("b", "c", "w")), shape)
        self.T = scatter_to_grid(edge_relation(self.src, self.dst,
                                               names=("c", "d", "x")), shape)

    @staticmethod
    def assert_bit_identical(a: Relation, b: Relation):
        assert a.names == b.names
        assert bool(jnp.all(a.valid == b.valid))
        for n in a.names:
            assert a.cols[n].dtype == b.cols[n].dtype
            assert bool(jnp.all(a.cols[n] == b.cols[n]))

    def test_one_round(self):
        caps = ChainCaps(recv=64, mid=512, out=2048, local=64)
        legacy, st_l, _ = one_round_three_way(
            self.grid, self.R, self.S, self.T, recv_capacity=64,
            mid_capacity=512, out_capacity=2048, local_capacity=64)
        query, st_q, _ = execute_chain(
            self.grid, ChainQuery.three_way(), (self.R, self.S, self.T),
            strategy="one_round", caps=caps)
        self.assert_bit_identical(legacy, query)
        assert float(st_l["read"]) == float(st_q["read"])
        assert float(st_l["shuffled"]) == float(st_q["shuffled"])

    def test_cascade(self):
        caps = ChainCaps(recv=64, mid=1024, out=4096, local=64)
        legacy, st_l, _ = cascade_three_way(
            self.grid, self.R, self.S, self.T, recv_capacity=64,
            mid_capacity=1024, out_capacity=4096, local_capacity=64)
        query, st_q, _ = execute_chain(
            self.grid, ChainQuery.three_way(), (self.R, self.S, self.T),
            strategy="cascade", caps=caps)
        self.assert_bit_identical(legacy, query)
        assert float(st_l["total"]) == float(st_q["total"])

    def test_cascade_pushdown(self):
        caps = ChainCaps(recv=64, mid=512, out=1024, local=64, agg=256)
        legacy, st_l, _ = cascade_three_way_agg(
            self.grid, self.R, self.S, self.T, recv_capacity=64,
            mid_capacity=512, agg_capacity=256, out_capacity=1024,
            local_capacity=64)
        query, st_q, _ = execute_chain(
            self.grid, ChainQuery.three_way(aggregate=True),
            (self.R, self.S, self.T), strategy="cascade_pushdown", caps=caps)
        self.assert_bit_identical(legacy, query)
        assert float(st_l["total"]) == float(st_q["total"])


class TestQueryValidation:
    def test_rejects_wrong_grid_rank(self):
        query = ChainQuery.chain(4)
        rng = np.random.default_rng(0)
        edges = [rand_edges(rng, 5, 10) for _ in range(4)]
        rels = chain_edge_inputs(query, edges, (2, 2))
        with pytest.raises(ValueError, match="rank-3"):
            one_round_chain(SimGrid((2, 2)), query, rels,
                            caps=ChainCaps(recv=32, mid=64, out=64))

    def test_rejects_bad_schema(self):
        from repro.core import ChainAggregate
        with pytest.raises(ValueError, match="distinct"):
            ChainQuery(attrs=("a", "b", "a"), values=("v", "w"))
        with pytest.raises(ValueError, match="endpoints"):
            ChainQuery(attrs=("a", "b", "c"), values=("v", "w"),
                       aggregate=ChainAggregate(keys=("a", "b")))
        with pytest.raises(ValueError, match="collides"):
            # A join attribute named like the aggregation output would
            # be silently overwritten by the pushdown product.
            ChainQuery(attrs=("a", "p", "c"), values=("v", "w"),
                       aggregate=ChainAggregate(keys=("a", "c")))

    def test_rejects_missing_columns(self):
        query = ChainQuery.chain(3)
        rng = np.random.default_rng(1)
        edges = [rand_edges(rng, 5, 10) for _ in range(3)]
        rels = chain_edge_inputs(query, edges, (2,))
        with pytest.raises(ValueError, match="missing"):
            cascade_chain(SimGrid((2,)), query, rels[::-1],
                          caps=ChainCaps(recv=32, mid=64, out=64))
