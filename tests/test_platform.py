"""configure_platform: flag construction in-process, full behaviour in
a subprocess (XLA flags only apply before JAX initializes, and pytest's
main process has long since initialized)."""

import os
import subprocess
import sys

import pytest

from repro.config import (GPU_OVERLAP_FLAGS, _merge_xla_flags,
                          configure_platform)


def test_merge_replaces_same_name_and_keeps_others(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_cpu_enable_fast_math=false "
                       "--xla_force_host_platform_device_count=2")
    merged = _merge_xla_flags(
        ("--xla_force_host_platform_device_count=8",)).split()
    assert "--xla_force_host_platform_device_count=8" in merged
    assert "--xla_force_host_platform_device_count=2" not in merged
    assert "--xla_cpu_enable_fast_math=false" in merged


def test_merge_is_idempotent(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "")
    _merge_xla_flags(GPU_OVERLAP_FLAGS)
    once = os.environ["XLA_FLAGS"]
    _merge_xla_flags(GPU_OVERLAP_FLAGS)
    assert os.environ["XLA_FLAGS"] == once


def test_after_init_warns_and_returns_false(monkeypatch):
    # pytest's process has run jax computations: the call must refuse
    # politely, not crash, and must not touch the environment.
    import jax
    jax.numpy.zeros(())  # ensure a backend exists
    monkeypatch.setenv("XLA_FLAGS", "--sentinel=1")
    with pytest.warns(RuntimeWarning, match="after JAX initialized"):
        applied = configure_platform(host_devices=4)
    assert applied is False
    assert os.environ["XLA_FLAGS"] == "--sentinel=1"


def test_host_devices_validation():
    with pytest.raises(ValueError, match="host_devices"):
        configure_platform(host_devices=0)


def test_configure_platform_subprocess():
    """Acceptance: a fresh process gets 16 emulated CPU devices, a mesh
    over them, idempotent flag merging, and the warn-after-init
    contract (tests/_platform_check.py)."""
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    out = subprocess.run(
        [sys.executable, "tests/_platform_check.py", "16"],
        capture_output=True, text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK 16" in out.stdout
