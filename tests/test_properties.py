"""Property-based tests (hypothesis) on the system's invariants.

The paper's core identities must hold for ARBITRARY relations and grid
shapes, not just the curated cases:

  P1  distributed join == oracle join (any keys, any grid)
  P2  measured communication == the paper's cost formula, exactly
  P3  1,3J and 2,3JA compute the same aggregated answer
  P4  crossover k* is exactly where the analytic costs cross
  P5  segment-sum kernel == oracle for any ids/values
  P6  error-feedback compression: per-block error bounded by scale/2,
      and the residual carries exactly what was lost
  P7  bucket hash: deterministic, in-range, salt-decorrelated
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core import (SimGrid, cascade_three_way_agg, edge_relation,
                        one_round_three_way_agg, oracle_a3, two_way_join)
from repro.core.cost_model import (cost_cascade, cost_one_round,
                                   crossover_reducers)
from repro.core.hashing import bucket_hash
from repro.distributed.compression import BLOCK, ef_compress, ef_init

SETTINGS = dict(max_examples=20, deadline=None)


def scatter(rel, shape):
    n_dev = int(np.prod(shape))
    cap = rel.capacity
    per = -(-cap // n_dev)
    pad = per * n_dev - cap
    cols = {k: jnp.pad(c, (0, pad)).reshape(tuple(shape) + (per,))
            for k, c in rel.cols.items()}
    valid = jnp.pad(rel.valid, (0, pad)).reshape(tuple(shape) + (per,))
    return type(rel)(cols, valid)


edges = st.integers(min_value=5, max_value=60)
nodes = st.integers(min_value=2, max_value=12)
grids = st.sampled_from([(2,), (4,), (2, 2), (2, 3)])


@settings(**SETTINGS)
@given(n_edges=edges, n_nodes=nodes, grid_shape=grids, seed=st.integers(0, 99))
def test_p1_p2_two_way_join(n_edges, n_nodes, grid_shape, seed):
    rng = np.random.default_rng(seed)
    a, b = (rng.integers(0, n_nodes, n_edges).astype(np.int32) for _ in "ab")
    c, d = (rng.integers(0, n_nodes, n_edges).astype(np.int32) for _ in "cd")
    R = scatter(edge_relation(a, b, names=("a", "b", "v")), grid_shape)
    S = scatter(edge_relation(c, d, names=("b", "c", "w")), grid_shape)
    grid = SimGrid(grid_shape)
    out, stats, ovf = two_way_join(grid, R, S, "b", "b",
                                   recv_capacity=256, out_capacity=4096)
    assert not bool(ovf)
    expect = {(int(x), int(y), int(z)) for x, y in zip(a, b)
              for y2, z in zip(c, d) if y == y2}
    got = set()
    flat = jax.tree.map(
        lambda x: x.reshape((-1,) + x.shape[len(grid_shape):]), out)
    for dev in range(flat.valid.shape[0]):
        sub = type(out)({k: v[dev] for k, v in flat.cols.items()},
                        flat.valid[dev])
        got |= sub.to_tuple_set(("a", "b", "c"))
    assert got == expect                       # P1
    assert float(stats["read"]) == 2 * n_edges     # P2
    assert float(stats["shuffled"]) == 2 * n_edges


@settings(max_examples=8, deadline=None)
@given(n_edges=st.integers(10, 40), n_nodes=st.integers(3, 8),
       seed=st.integers(0, 99))
def test_p3_agg_algorithms_agree(n_edges, n_nodes, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    grid = SimGrid((2, 2))
    R = scatter(edge_relation(src, dst, names=("a", "b", "v")), (2, 2))
    S = scatter(edge_relation(src, dst, names=("b", "c", "w")), (2, 2))
    T = scatter(edge_relation(src, dst, names=("c", "d", "x")), (2, 2))
    kw = dict(recv_capacity=256, mid_capacity=4096, local_capacity=256)
    o1, _, ovf1 = one_round_three_way_agg(grid, R, S, T, join_capacity=32768,
                                          out_capacity=8192, **kw)
    o2, _, ovf2 = cascade_three_way_agg(grid, R, S, T, agg_capacity=4096,
                                        out_capacity=32768, **kw)
    assert not bool(ovf1) and not bool(ovf2)
    expect = oracle_a3(src, dst)

    def collect(out):
        got = {}
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), out)
        for dev in range(flat.valid.shape[0]):
            sub = type(out)({k: v[dev] for k, v in flat.cols.items()},
                            flat.valid[dev])
            dd = sub.to_numpy()
            for aa, d2, p in zip(dd["a"], dd["d"], dd["p"]):
                got[(int(aa), int(d2))] = got.get((int(aa), int(d2)), 0.0) + float(p)
        return got

    g1, g2 = collect(o1), collect(o2)
    assert set(g1) == set(g2) == set(expect)
    for k in expect:
        np.testing.assert_allclose(g1[k], expect[k], rtol=1e-5)
        np.testing.assert_allclose(g2[k], expect[k], rtol=1e-5)


@settings(max_examples=50, deadline=None)
@given(r=st.floats(10, 1e7), j1_factor=st.floats(1.1, 500.0))
def test_p4_crossover_is_exact(r, j1_factor):
    j1 = r * j1_factor
    k_star = crossover_reducers(r, r, r, j1)
    below = cost_one_round(r, r, r, max(int(k_star * 0.96), 1))
    above = cost_one_round(r, r, r, int(k_star * 1.04) + 1)
    c23 = cost_cascade(r, r, r, j1)
    assert below <= c23 * (1 + 1e-6)
    assert above >= c23 * (1 - 1e-6)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 2000), n_seg=st.integers(1, 300),
       seed=st.integers(0, 99))
def test_p5_segment_sum_kernel(n, n_seg, seed):
    from repro.kernels import ref
    from repro.kernels.segment_sum import segment_sum
    rng = np.random.default_rng(seed)
    ids = rng.integers(-2, n_seg + 2, n).astype(np.int32)  # incl. out-of-range
    vals = rng.normal(size=n).astype(np.float32)
    got = segment_sum(jnp.array(vals), jnp.array(ids), n_seg,
                      interpret=True, seg_tile=128, block=128)
    want = ref.segment_sum(jnp.array(vals), jnp.array(ids), n_seg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 1000), scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 99))
def test_p6_compression_error_feedback(n, scale, seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.array(rng.normal(size=n) * scale, jnp.float32)}
    res = ef_init(g)
    gc, res2 = ef_compress(g, res)
    err = np.asarray(g["w"]) - np.asarray(gc["w"])
    # residual must equal exactly what quantization lost
    np.testing.assert_allclose(np.asarray(res2["w"]), err, rtol=1e-5,
                               atol=1e-6 * scale)
    # per-block error bound: half a quantization step
    flat = np.abs(np.asarray(g["w"]))
    pad = -n % BLOCK
    blocks = np.pad(flat, (0, pad)).reshape(-1, BLOCK)
    step = blocks.max(axis=1) / 127.0
    bound = np.repeat(step / 2 + 1e-6, BLOCK)[:n] + 1e-5 * scale
    assert np.all(np.abs(err) <= bound + 1e-6)


@settings(max_examples=30, deadline=None)
@given(keys=st.lists(st.integers(-2**31, 2**31 - 1), min_size=1, max_size=64),
       k=st.integers(1, 97), salt=st.integers(0, 3))
def test_p7_bucket_hash(keys, k, salt):
    x = jnp.array(np.array(keys, np.int64).astype(np.int32))
    h1 = np.asarray(bucket_hash(x, k, salt))
    h2 = np.asarray(bucket_hash(x, k, salt))
    np.testing.assert_array_equal(h1, h2)          # deterministic
    assert h1.min() >= 0 and h1.max() < k          # in-range
