"""Hypergraph query IR → planner → executor tests.

* `JoinQuery` validation: cycles/stars construct, disconnected or
  malformed hypergraphs are rejected, `ChainQuery` is a validated
  special case (same general machinery, chain-specific errors kept).
* Triangle and star queries execute on SimGrid via both strategies and
  match a brute-force host reference — including the cycle-closing
  filter at the one-round reduce side and the cascade's closing hop.
* Triangle counting is a query: the cycle path equals the chain+filter
  oracle path and `oracle_triangles` on R-MAT and Zipf graphs.
* Measured communication equals the general cost model exactly.
* Chain queries through the general surface are bit-identical to the
  chain surface, and `plan_query` delegates to `plan_chain`.
* `JoinQuery.triangle()` runs on a real ShardGrid (subprocess with 8
  emulated devices) with the same count and Shares accounting.
"""

import itertools
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    ChainCaps, ChainQuery, JoinQuery, QueryAggregate, Relation, SimGrid,
    cascade_query, chain_edge_inputs, chain_stats_exact, cost_query_cascade,
    default_query_caps, execute_chain, execute_query, jit_execute_query,
    one_round_chain, one_round_query, oracle_triangles, plan_chain,
    plan_query, query_replications, query_stats_exact, query_table_inputs,
    triangle_count_chain_filter, triangle_count_cycle,
)
from repro.data.graphs import (DATASETS, GraphSpec, rmat_edges, star_edges,
                               zipf_edges)


def rand_edges(rng, n_nodes, n_edges):
    return (rng.integers(0, n_nodes, n_edges).astype(np.int32),
            rng.integers(0, n_nodes, n_edges).astype(np.int32))


def host_reference(query: JoinQuery, tables) -> set:
    """Brute-force nested-loop join: every combination of one row per
    relation that agrees on all shared attributes.  Independent of the
    engine and of the planner's host hash joins."""
    rows = [list(zip(*[np.asarray(c).tolist() for c in t[:len(query.relations[j])]]))
            for j, t in enumerate(tables)]
    out = set()
    for combo in itertools.product(*rows):
        binding = {}
        ok = True
        for rel_attrs, row in zip(query.relations, combo):
            for a, v in zip(rel_attrs, row):
                if binding.setdefault(a, v) != v:
                    ok = False
                    break
            if not ok:
                break
        if ok:
            out.add(tuple(binding[a] for a in query.attrs))
    return out


def collect_tuples(out: Relation, grid_rank: int, names) -> set:
    flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[grid_rank:]), out)
    got = set()
    for dev in range(flat.valid.shape[0]):
        sub = Relation({k: v[dev] for k, v in flat.cols.items()},
                       flat.valid[dev])
        got |= sub.to_tuple_set(names)
    return got


# ---------------------------------------------------------------------------
# IR validation
# ---------------------------------------------------------------------------

class TestJoinQueryIR:
    def test_triangle_shape(self):
        q = JoinQuery.triangle()
        assert q.relations == (("a", "b"), ("b", "c"), ("c", "a"))
        assert q.join_attrs == ("a", "b", "c") and q.n_dims == 3
        assert q.rel_dims() == ((0, 1), (1, 2), (0, 2))
        assert q.chain_attr_order() is None          # a cycle, not a chain

    def test_star_shape(self):
        q = JoinQuery.star(3)
        assert q.relations == (("a", "b"), ("a", "c"), ("a", "d"))
        assert q.join_attrs == ("a",) and q.n_dims == 1
        assert q.rel_dims() == ((0,), (0,), (0,))

    def test_chain_is_a_join_query(self):
        c = ChainQuery.three_way()
        assert isinstance(c, JoinQuery)
        assert c.relations == (("a", "b"), ("b", "c"), ("c", "d"))
        assert c.chain_attr_order() == ("a", "b", "c", "d")
        # The general JoinQuery.chain builds the same hypergraph.
        j = JoinQuery.chain(3)
        assert j.relations == c.relations and j.join_attrs == c.join_attrs

    def test_rejects_disconnected(self):
        with pytest.raises(ValueError, match="connected"):
            JoinQuery(attrs=("a", "b", "c", "d"),
                      relations=(("a", "b"), ("c", "d")),
                      values=(None, None))

    def test_rejects_malformed(self):
        with pytest.raises(ValueError, match="repeats"):
            JoinQuery(attrs=("a", "b"), relations=(("a", "a"), ("a", "b")),
                      values=(None, None))
        with pytest.raises(ValueError, match="universe"):
            JoinQuery(attrs=("a", "b"), relations=(("a", "b"), ("b", "z")),
                      values=(None, None))
        with pytest.raises(ValueError, match="no relation"):
            JoinQuery(attrs=("a", "b", "z"), relations=(("a", "b"), ("b", "a")),
                      values=(None, None))
        with pytest.raises(ValueError, match="group key"):
            JoinQuery(attrs=("a", "b", "c"),
                      relations=(("a", "b"), ("b", "c")), values=("v", "w"),
                      aggregate=QueryAggregate(keys=()))

    def test_chain_validation_messages_kept(self):
        from repro.core import ChainAggregate
        with pytest.raises(ValueError, match="distinct"):
            ChainQuery(attrs=("a", "b", "a"), values=("v", "w"))
        with pytest.raises(ValueError, match="endpoints"):
            ChainQuery(attrs=("a", "b", "c"), values=("v", "w"),
                       aggregate=ChainAggregate(keys=("a", "b")))

    def test_join_orders(self):
        t = JoinQuery.triangle()
        assert t.default_join_order() == (0, 1, 2)
        q = JoinQuery(attrs=("a", "b", "c"),
                      relations=(("a", "b"), ("a", "c"), ("b", "c")),
                      values=(None, None, None))
        assert q.chain_attr_order() is None          # a clique

    def test_queries_are_hashable(self):
        assert hash(JoinQuery.triangle()) == hash(JoinQuery.triangle())
        assert JoinQuery.star(3) != JoinQuery.triangle()
        assert hash(ChainQuery.three_way()) == hash(ChainQuery.three_way())


# ---------------------------------------------------------------------------
# Executor equivalence on SimGrid
# ---------------------------------------------------------------------------

CAPS = ChainCaps(recv=512, mid=8192, out=16384, local=2048, agg=4096,
                 join=16384)


class TestTriangleExecution:
    def setup_method(self, method):
        rng = np.random.default_rng(11)
        self.edges = rand_edges(rng, 16, 56)
        self.tables = [self.edges] * 3
        self.query = JoinQuery.triangle()
        self.expect = host_reference(self.query, self.tables)
        assert self.expect, "degenerate test: no triangles"

    def test_one_round_matches_reference(self):
        grid_shape = (2, 2, 2)
        grid = SimGrid(grid_shape)
        rels = query_table_inputs(self.query, self.tables, grid_shape)
        out, st, ovf = one_round_query(grid, self.query, rels, caps=CAPS)
        assert not bool(ovf)
        assert collect_tuples(out, 3, self.query.attrs) == self.expect
        # Shares accounting, exactly: read Σr, shuffle Σ r·K/m_j.
        sizes = (float(len(self.edges[0])),) * 3
        repl = query_replications(self.query.rel_dims(), grid_shape)
        assert float(st["read"]) == sum(sizes)
        assert float(st["shuffled"]) == sum(r * f for r, f in zip(sizes, repl))

    def test_cascade_matches_reference_and_cost(self):
        stats = query_stats_exact(self.query, self.tables)
        order, analytic = stats.best_order()
        grid = SimGrid((4,))
        rels = query_table_inputs(self.query, self.tables, (4,))
        out, st, ovf = cascade_query(grid, self.query, rels, caps=CAPS,
                                     join_order=order)
        assert not bool(ovf)
        assert collect_tuples(out, 1, self.query.attrs) == self.expect
        assert float(st["total"]) == analytic

    def test_all_join_orders_agree(self):
        stats = query_stats_exact(self.query, self.tables)
        grid = SimGrid((2, 2, 2))
        rels = query_table_inputs(self.query, self.tables, (2, 2, 2))
        for order in stats.orders:
            out, _, ovf = one_round_query(grid, self.query, rels, caps=CAPS,
                                          join_order=order)
            assert not bool(ovf)
            assert collect_tuples(out, 3, self.query.attrs) == self.expect

    def test_all_pairs_oracle_kernel_agrees(self):
        grid = SimGrid((2, 2, 2))
        rels = query_table_inputs(self.query, self.tables, (2, 2, 2))
        out, _, ovf = one_round_query(grid, self.query, rels, caps=CAPS,
                                      join_impl="all_pairs")
        assert not bool(ovf)
        assert collect_tuples(out, 3, self.query.attrs) == self.expect

    def test_jit_execute_query(self):
        grid = SimGrid((2, 2, 2))
        rels = query_table_inputs(self.query, self.tables, (2, 2, 2))
        run = jit_execute_query(grid, self.query, strategy="one_round",
                                caps=CAPS, donate=False)
        out, st, ovf = run(tuple(rels))
        assert not bool(ovf)
        assert collect_tuples(out, 3, self.query.attrs) == self.expect
        # Cache hit: same (shape, query, strategy, caps, opts) program.
        assert run is jit_execute_query(SimGrid((2, 2, 2)), self.query,
                                        strategy="one_round", caps=CAPS,
                                        donate=False)


class TestStarExecution:
    def setup_method(self, method):
        self.edges = star_edges(6, 20, 48, fanout_skew=0.8, seed=5)
        self.query = JoinQuery.star(3)
        self.tables = [self.edges] * 3
        self.expect = host_reference(self.query, self.tables)
        assert self.expect

    def test_one_round_single_dim(self):
        # The star hypercube degenerates to one dim (the hub): hash
        # everything on it, replicate nothing.
        grid = SimGrid((4,))
        rels = query_table_inputs(self.query, self.tables, (4,))
        out, st, ovf = one_round_query(grid, self.query, rels, caps=CAPS)
        assert not bool(ovf)
        assert collect_tuples(out, 1, self.query.attrs) == self.expect
        n = float(len(self.edges[0]))
        assert float(st["read"]) == 3 * n
        assert float(st["shuffled"]) == 3 * n      # replication factor 1

    def test_cascade_agrees(self):
        grid = SimGrid((2, 2))
        rels = query_table_inputs(self.query, self.tables, (2, 2))
        out, _, ovf = cascade_query(grid, self.query, rels, caps=CAPS)
        assert not bool(ovf)
        assert collect_tuples(out, 2, self.query.attrs) == self.expect

    def test_aggregated_star(self):
        query = JoinQuery.star(3, aggregate=True)
        grid = SimGrid((4,))
        rels = query_table_inputs(query, self.tables, (4,))
        out, _, ovf = one_round_query(grid, query, rels, caps=CAPS)
        assert not bool(ovf)
        # Γ_{hub; SUM ∏ 1} = outdeg³ per hub.
        hub, _ = self.edges
        deg = np.bincount(hub).astype(np.float64)
        want = {(int(h),): float(deg[h] ** 3) for h in np.unique(hub)}
        got = {}
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[1:]), out)
        for dev in range(flat.valid.shape[0]):
            sub = Relation({k: v[dev] for k, v in flat.cols.items()},
                           flat.valid[dev])
            d = sub.to_numpy()
            for h, p in zip(d["a"], d["p"]):
                got[(int(h),)] = got.get((int(h),), 0.0) + float(p)
        assert got == want


# ---------------------------------------------------------------------------
# Chains through the general surface: unchanged
# ---------------------------------------------------------------------------

class TestChainCompatibility:
    def setup_method(self, method):
        rng = np.random.default_rng(4)
        self.edges = [rand_edges(rng, 12, 40) for _ in range(3)]

    def test_execute_query_bit_identical_to_execute_chain(self):
        query = ChainQuery.three_way()
        rels = chain_edge_inputs(query, self.edges, (2, 2))
        grid = SimGrid((2, 2))
        caps = ChainCaps(recv=64, mid=512, out=2048, local=64)
        a, st_a, _ = execute_chain(grid, query, rels, strategy="one_round",
                                   caps=caps)
        b, st_b, _ = execute_query(grid, query, rels, strategy="one_round",
                                   caps=caps)
        assert a.names == b.names
        assert bool(jnp.all(a.valid == b.valid))
        for n in a.names:
            assert bool(jnp.all(a.cols[n] == b.cols[n]))
        assert float(st_a["shuffled"]) == float(st_b["shuffled"])

    def test_plan_query_delegates_to_plan_chain(self):
        query = ChainQuery.three_way()
        stats = query_stats_exact(query, self.edges)
        assert stats.chain is not None
        qplan = plan_query(query, stats, k=16)
        cplan = plan_chain(chain_stats_exact(self.edges), k=16,
                           aggregate=False)
        assert qplan.algorithm == cplan.algorithm
        assert qplan.strategy == cplan.strategy
        assert qplan.grid_shape == cplan.grid_shape
        assert qplan.costs == cplan.costs
        assert qplan.chain_plan is not None

    def test_general_one_round_handles_plain_chain_joinquery(self):
        # The same chain hypergraph built as a bare JoinQuery runs
        # identically to the ChainQuery path.
        cq = ChainQuery.chain(3)
        jq = JoinQuery.chain(3)
        rels = chain_edge_inputs(cq, self.edges, (2, 2))
        grid = SimGrid((2, 2))
        caps = ChainCaps(recv=64, mid=512, out=2048, local=64)
        a, _, _ = one_round_chain(grid, cq, rels, caps=caps)
        b, _, _ = one_round_query(grid, jq, rels, caps=caps)
        assert a.names == b.names
        assert bool(jnp.all(a.valid == b.valid))
        for n in a.names:
            assert bool(jnp.all(a.cols[n] == b.cols[n]))


# ---------------------------------------------------------------------------
# Triangle counting is a query, not an algorithm (regression vs oracles)
# ---------------------------------------------------------------------------

def thirds(x):
    return round(3.0 * x)


class TestTriangleRegression:
    @pytest.mark.parametrize("graph", ["rmat", "zipf"])
    def test_cycle_equals_chain_filter_and_oracle(self, graph):
        if graph == "rmat":
            spec = DATASETS["amazon"]
            src, dst = rmat_edges(GraphSpec(spec.name, 7, 3.0, spec.a),
                                  seed=2)
        else:
            # Small but genuinely skewed: the top hub concentrates a
            # constant fraction of every join attribute.
            src, dst = zipf_edges(96, 220, 1.1, seed=2)
        want = oracle_triangles(src, dst)

        got, plan, st, ovf = triangle_count_cycle(src, dst, k=8,
                                                  caps_slack=16)
        assert not bool(ovf)
        assert thirds(got) == thirds(want)

        # The chain+filter oracle path (full 3-chain + diagonal) with
        # lossless (total-sized) buffers: on skewed graphs one reducer
        # can hold nearly the whole intermediate.
        cstats = chain_stats_exact([(src, dst)] * 3)
        big = int(max(cstats.prefix_joins)) + 256
        caps = {"input": len(src), "recv": big, "mid": big,
                "agg": int(max(cstats.prefix_aggs)) + 256,
                "join": big, "out": big, "local": big}
        chain_got, _, ovf_c = triangle_count_chain_filter(
            SimGrid((4, 2)), src, dst, caps=caps)
        assert not bool(ovf_c)
        assert thirds(chain_got) == thirds(want)
        assert thirds(got) == thirds(chain_got)


# ---------------------------------------------------------------------------
# ShardGrid: the production backend runs the triangle query
# ---------------------------------------------------------------------------

def test_triangle_on_shard_grid_subprocess():
    """Acceptance: JoinQuery.triangle() executes via execute_query on a
    real 2×2×2 ShardGrid mesh (subprocess keeps pytest single-device)."""
    out = subprocess.run(
        [sys.executable, "tests/_query_shard_check.py"],
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
