"""General-shares solver + integer shares: properties and closed forms.

The hypergraph Shares machinery must (a) reproduce the chain closed
forms bit-for-bit on chain incidences, (b) recover the classic
``k^{1/3}`` symmetric shares on the uniform triangle, and (c) hold the
structural invariants for arbitrary incidences: executable share
products never exceed the budget, real share products use exactly the
budget, the solver never loses to other feasible share vectors, and the
(1,…,1) grid is the replication-free communication lower bound every
share vector pays at least.
"""

import math

import numpy as np
import pytest

from repro.core import (
    JoinQuery, cost_query_one_round, integer_shares, integer_shares_query,
    optimal_shares_chain, optimal_shares_query, query_replications,
)

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

SETTINGS = dict(max_examples=40, deadline=None)

# A pool of genuinely different incidences: chains, cycles, stars, a
# clique, and a mixed shape (per-relation pinned-dim tuples).
INCIDENCES = {
    "chain3": ((0,), (0, 1), (1,)),
    "chain4": ((0,), (0, 1), (1, 2), (2,)),
    "triangle": ((0, 1), (1, 2), (0, 2)),
    "cycle4": ((0, 1), (1, 2), (2, 3), (0, 3)),
    "star3": ((0,), (0,), (0,)),
    "clique3": ((0, 1), (0, 2), (1, 2), (0, 1)),
    "mixed": ((0, 1, 2), (0,), (1,), (2,)),
}

sizes_for = st.floats(min_value=1.0, max_value=1e6)
budgets = st.integers(min_value=1, max_value=4096)
incidences = st.sampled_from(sorted(INCIDENCES))


@given(name=incidences, k=budgets, data=st.data())
@settings(**SETTINGS)
def test_integer_shares_feasible(name, k, data):
    """∏ shares ≤ k, every share a positive int."""
    rel_dims = INCIDENCES[name]
    sizes = data.draw(st.lists(sizes_for, min_size=len(rel_dims),
                               max_size=len(rel_dims)))
    shares = integer_shares_query(rel_dims, sizes, k)
    assert all(isinstance(s, int) and s >= 1 for s in shares)
    assert math.prod(shares) <= k


@given(name=incidences, k=budgets, data=st.data())
@settings(**SETTINGS)
def test_real_shares_use_the_budget_and_stay_feasible(name, k, data):
    rel_dims = INCIDENCES[name]
    sizes = data.draw(st.lists(sizes_for, min_size=len(rel_dims),
                               max_size=len(rel_dims)))
    shares = optimal_shares_query(rel_dims, sizes, k)
    assert min(shares) >= 1.0 - 1e-6
    if k > 1:
        assert math.prod(shares) == pytest.approx(k, rel=1e-3)


@given(name=incidences, k=budgets, data=st.data())
@settings(**SETTINGS)
def test_ones_grid_is_the_replication_free_lower_bound(name, k, data):
    """Cost on the (1,…,1) grid is exactly 2·Σr (read + unreplicated
    shuffle); every share vector — the solver's included — pays at
    least that."""
    rel_dims = INCIDENCES[name]
    dims = 1 + max(d for D in rel_dims for d in D)
    sizes = data.draw(st.lists(sizes_for, min_size=len(rel_dims),
                               max_size=len(rel_dims)))
    ones_cost = cost_query_one_round(rel_dims, sizes, 1,
                                     shares=(1.0,) * dims)
    assert ones_cost == pytest.approx(2.0 * sum(sizes), rel=1e-12)
    opt_cost = cost_query_one_round(rel_dims, sizes, k)
    int_shares = integer_shares_query(rel_dims, sizes, k)
    int_cost = cost_query_one_round(rel_dims, sizes, math.prod(int_shares),
                                    shares=int_shares)
    assert opt_cost >= ones_cost * (1 - 1e-9)
    assert int_cost >= ones_cost * (1 - 1e-9)


@given(name=incidences, k=st.integers(min_value=2, max_value=4096),
       data=st.data())
@settings(**SETTINGS)
def test_solver_never_loses_to_feasible_alternatives(name, k, data):
    """The solver's cost ≤ the cost of uniform shares, axis-aligned
    corners, and random feasible vectors with the same budget."""
    rel_dims = INCIDENCES[name]
    dims = 1 + max(d for D in rel_dims for d in D)
    sizes = data.draw(st.lists(sizes_for, min_size=len(rel_dims),
                               max_size=len(rel_dims)))
    opt = cost_query_one_round(rel_dims, sizes, k)

    candidates = [(float(k ** (1.0 / dims)),) * dims]
    for d in range(dims):
        corner = [1.0] * dims
        corner[d] = float(k)
        candidates.append(tuple(corner))
    # Mixed-boundary candidates (some dims clamped at 1, the budget
    # split over the rest) — the regime where gradient descent stalls.
    for mask in range(1, 2 ** dims - 1):
        free = [d for d in range(dims) if mask >> d & 1]
        cand = [1.0] * dims
        for d in free:
            cand[d] = float(k ** (1.0 / len(free)))
        candidates.append(tuple(cand))
    # Random feasible interior vectors: exp of random points on the
    # positive simplex scaled to ln k.
    for _ in range(6):
        w = np.asarray(data.draw(st.lists(
            st.floats(min_value=0.05, max_value=1.0),
            min_size=dims, max_size=dims)))
        candidates.append(tuple(math.exp(v) for v in
                                np.log(k) * w / w.sum()))
    for cand in candidates:
        c = cost_query_one_round(rel_dims, sizes, k, shares=cand)
        assert opt <= c * (1 + 1e-4)


@given(k=budgets, data=st.data())
@settings(**SETTINGS)
def test_chain_incidence_reproduces_chain_solver_bit_for_bit(k, data):
    """Acceptance: on chains the general solver must equal
    `optimal_shares_chain` exactly — it delegates to the same closed
    form — and the integer refinement must equal `integer_shares`."""
    n = data.draw(st.integers(min_value=3, max_value=6))
    sizes = data.draw(st.lists(sizes_for, min_size=n, max_size=n))
    rel_dims = JoinQuery.chain(n).rel_dims()
    assert optimal_shares_query(rel_dims, sizes, k) == \
        optimal_shares_chain(sizes, k)
    assert integer_shares_query(rel_dims, sizes, k) == \
        integer_shares(sizes, k)


class TestTriangleClosedForm:
    def test_uniform_triangle_gets_cuberoot_shares(self):
        """Acceptance: the symmetric triangle recovers the classic
        k^{1/3} per-attribute share."""
        rel_dims = JoinQuery.triangle().rel_dims()
        for r, k in [(100.0, 8), (1e5, 64), (3e4, 1000)]:
            shares = optimal_shares_query(rel_dims, (r, r, r), k)
            want = k ** (1.0 / 3.0)
            for s in shares:
                assert s == pytest.approx(want, rel=1e-9)
            # ... and the cost is the classic 3r + 3r·k^{1/3}.
            got = cost_query_one_round(rel_dims, (r, r, r), k, shares)
            assert got == pytest.approx(3 * r + 3 * r * want, rel=1e-9)

    def test_asymmetric_triangle_balances_kkt(self):
        """At the interior optimum every dim carries equal total
        communication (the Lagrangean alternation's fixed point)."""
        rel_dims = JoinQuery.triangle().rel_dims()
        sizes, k = (100.0, 400.0, 900.0), 4096
        shares = optimal_shares_query(rel_dims, sizes, k)
        repl = query_replications(rel_dims, shares)
        t = [r * f for r, f in zip(sizes, repl)]
        g = [t[0] + t[2], t[0] + t[1], t[1] + t[2]]  # per-dim totals
        assert max(g) == pytest.approx(min(g), rel=1e-6)

    def test_mixed_boundary_optima_are_found(self):
        """Regression: asymmetric chains whose optimum clamps *interior*
        dims (e.g. (1, 32, 1, 32)) — where plain projected gradient
        stalls far from the boundary — must be priced at the true
        constrained optimum."""
        from repro.core import cost_chain_one_round
        sizes, k = (1.0, 1000.0, 1000.0, 1000.0, 1000.0), 1024
        shares = optimal_shares_chain(sizes, k)
        got = cost_chain_one_round(sizes, k, shares)
        want = cost_chain_one_round(sizes, k, (1.0, 32.0, 1.0, 32.0))
        assert got == pytest.approx(want, rel=1e-9)

        sizes6, k6 = (1.0, 10.0, 1e6, 1e8, 1e8, 1.0), 1024
        rel_dims = JoinQuery.chain(6).rel_dims()
        got6 = cost_query_one_round(rel_dims, sizes6, k6)
        # True optimum puts the whole budget on the two heavy interior
        # dims (≈ (1, 1, 3.2, 320, 1)): verified by grid search.
        assert got6 <= 941.1e6

    def test_star_degenerates_to_hub_hashing(self):
        rel_dims = JoinQuery.star(4).rel_dims()
        sizes = (10.0, 20.0, 30.0, 40.0)
        assert optimal_shares_query(rel_dims, sizes, 64) == (64.0,)
        assert integer_shares_query(rel_dims, sizes, 64) == (64,)
        # No replication: the one-round cost is the 2Σr lower bound.
        assert cost_query_one_round(rel_dims, sizes, 64) == \
            pytest.approx(2 * sum(sizes), rel=1e-12)
