"""JoinQuery validation error paths: every malformed hypergraph is
rejected at construction with an actionable message (the static
verifier builds on these invariants — a query that constructs is a
query the plan checker can reason about)."""

import pytest

from repro.core import ChainQuery, JoinQuery, QueryAggregate


def triangle_parts():
    return dict(attrs=("a", "b", "c"),
                relations=(("a", "b"), ("b", "c"), ("a", "c")),
                values=("v", "w", "x"))


class TestStructure:
    def test_needs_two_relations(self):
        with pytest.raises(ValueError, match=">= 2 relations"):
            JoinQuery(attrs=("a", "b"), relations=(("a", "b"),),
                      values=(None,))

    def test_values_arity_must_match(self):
        with pytest.raises(ValueError, match="value entries"):
            JoinQuery(attrs=("a", "b", "c"),
                      relations=(("a", "b"), ("b", "c")), values=("v",))

    def test_empty_relation(self):
        with pytest.raises(ValueError, match="no attributes"):
            JoinQuery(attrs=("a", "b"), relations=((), ("a", "b")),
                      values=(None, None))

    def test_duplicate_attribute_within_relation(self):
        with pytest.raises(ValueError, match="repeats an attribute"):
            JoinQuery(attrs=("a", "b"), relations=(("a", "a"), ("a", "b")),
                      values=(None, None))

    def test_attribute_outside_universe(self):
        with pytest.raises(ValueError, match="outside the universe"):
            JoinQuery(attrs=("a", "b"), relations=(("a", "b"), ("b", "z")),
                      values=(None, None))

    def test_dangling_attribute(self):
        """An attribute of the universe no relation mentions."""
        with pytest.raises(ValueError, match="appear in no relation"):
            JoinQuery(attrs=("a", "b", "ghost"),
                      relations=(("a", "b"), ("b", "a")),
                      values=(None, None))

    def test_attr_value_name_collision(self):
        with pytest.raises(ValueError, match="must be distinct"):
            JoinQuery(attrs=("a", "b", "c"),
                      relations=(("a", "b"), ("b", "c")),
                      values=("a", None))

    def test_reserved_cycle_closing_prefix(self):
        with pytest.raises(ValueError, match="reserved '_cc_' prefix"):
            JoinQuery(attrs=("a", "_cc_b"),
                      relations=(("a", "_cc_b"), ("_cc_b", "a")),
                      values=(None, None))

    def test_disconnected_hypergraph(self):
        with pytest.raises(ValueError, match="must be connected"):
            JoinQuery(attrs=("a", "b", "c", "d"),
                      relations=(("a", "b"), ("c", "d")),
                      values=(None, None))


class TestAggregateValidation:
    def test_aggregate_needs_values_everywhere(self):
        parts = triangle_parts()
        parts["values"] = ("v", None, "x")
        with pytest.raises(ValueError, match="value column on"):
            JoinQuery(aggregate=QueryAggregate(keys=("a",)), **parts)

    def test_aggregate_needs_a_key(self):
        with pytest.raises(ValueError, match="at least one group key"):
            JoinQuery(aggregate=QueryAggregate(keys=()), **triangle_parts())

    def test_aggregate_keys_must_be_attributes(self):
        with pytest.raises(ValueError, match="distinct"):
            JoinQuery(aggregate=QueryAggregate(keys=("a", "zz")),
                      **triangle_parts())

    def test_aggregate_out_collision(self):
        with pytest.raises(ValueError, match="collides"):
            JoinQuery(aggregate=QueryAggregate(keys=("a",), out="w"),
                      **triangle_parts())


class TestJoinOrders:
    def test_non_permutation_order_rejected(self):
        q = JoinQuery(**triangle_parts())
        with pytest.raises(ValueError):
            q.join_steps((0, 2, 2))

    def test_triangle_closing_step(self):
        """The triangle's final hop carries the cycle-closing filter."""
        q = JoinQuery(**triangle_parts())
        _, key, extras = q.join_steps()[-1]
        assert len(extras) == 1
        assert {key, *extras} == {"a", "c"}

    def test_chain_query_round_trips(self):
        q = ChainQuery.chain(4)
        assert q.default_join_order() == (0, 1, 2, 3)
        assert all(extras == () for _, _, extras in q.join_steps())
