"""Seeded chaos suite: resilient execution under deterministic faults.

The invariant every test here pins (docs/resilience.md): a faulted run
returns the fault-free answer **bit-identically** or dies with a typed
error — never a silently wrong answer.  ``CHAOS_SEED`` (env) rotates
the injector seed across CI matrix entries without touching the code.

  R1  injector determinism, tracer-safety, kill-switch semantics
  R2  torn/corrupt checkpoints are skipped, never resumed from
  R3  cascade recovery — in-memory hop retry, snapshot resume after a
      killed process, corrupt-snapshot quarantine (all bitwise)
  R4  one-round recovery — failed reducer buckets re-run alone and
      splice bitwise; placement retries
  R5  partition reads — CRC-caught corruption retried, exhaustion
      quarantines; the semantic layout audit above the CRCs
  R6  serving admission control — queue shedding, deadlines, SLO
      shedding, the plan/compile circuit breaker, submit-fault retry
  R7  graceful degradation — stale map-side certificate serves the
      exact answer via the shuffle cascade; delta-maintenance failure
      falls back to recompute; permanent failure leaves the store
      unchanged; GC killed mid-delete is completed by the next open
  R8  the chaos matrix — {crash, delay, corrupt} × {shuffle,
      partition_read, submit}: exact equality or typed error, always
"""

import dataclasses
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (DataCorrupt, latest_hop, latest_step, save,
                              save_hop, save_partitioned)
from repro.core import (ChainQuery, JoinQuery, SimGrid, chain_partitioning,
                        chain_stats_exact, default_query_caps, edge_relation,
                        integer_shares_query, oracle_triangles,
                        partition_relation, query_stats_exact,
                        query_table_inputs, verify_partition_layout)
from repro.core.executor import cascade_query, one_round_query
from repro.resilience import (FaultInjector, FaultSpec, HopFailed,
                              InjectedCrash, RecoveryPolicy,
                              resilient_cascade_query,
                              resilient_load_partitioned,
                              resilient_one_round_query)
from repro.resilience import faults as faults_mod
from repro.serving import (QueryEngine, QueryRequest, QueryServeConfig,
                           ServingStore)

#: CI chaos matrix rotates this without code changes.
CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))

K = 4
M_EDGES = 48
N_NODES = 24


def _tables(seed=5, m=M_EDGES, nodes=N_NODES, n=3):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, nodes, m).astype(np.int32),
             rng.integers(0, nodes, m).astype(np.int32))
            for _ in range(n)]


def _rot_hop_npz(path):
    """Corrupt one array inside a hop snapshot's npz.  Rewriting a
    mutated array (rather than flipping a raw byte, which can land in
    inert zip padding) guarantees a manifest-CRC mismatch."""
    npz = os.path.join(path, "arrays.npz")
    with np.load(npz) as data:
        arrays = {k: np.array(data[k]) for k in data.files}
    k = sorted(arrays)[0]
    flat = arrays[k].reshape(-1)
    flat[0] = ~flat[0] if flat.dtype != np.bool_ else ~flat[0]
    np.savez(npz, **arrays)


def trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        x.shape == y.shape and x.dtype == y.dtype
        and bool(jnp.all(x == y)) for x, y in zip(la, lb))


@pytest.fixture(scope="module")
def chain3():
    """The 3-chain workload in both physical configurations, with the
    plain executors' fault-free results as the bitwise baselines."""
    query = JoinQuery.chain(3)
    tables = _tables()
    stats = query_stats_exact(query, tables)
    or_shape = integer_shares_query(query.rel_dims(), stats.sizes, K)
    c_shape = (K,)
    w = {
        "query": query,
        "or_grid": SimGrid(or_shape),
        "c_grid": SimGrid(c_shape),
        "or_rels": query_table_inputs(query, tables, or_shape),
        "c_rels": query_table_inputs(query, tables, c_shape),
        "or_caps": default_query_caps(query, stats, or_shape, slack=8),
        "c_caps": default_query_caps(query, stats, c_shape, slack=8),
    }
    w["base_or"] = one_round_query(w["or_grid"], query, w["or_rels"],
                                   caps=w["or_caps"], join_order=(0, 1, 2))
    w["base_c"] = cascade_query(w["c_grid"], query, w["c_rels"],
                                caps=w["c_caps"], join_order=(0, 1, 2))
    return w


def run_cascade(w, snapshot_dir=None, policy=None):
    return resilient_cascade_query(
        w["c_grid"], w["query"], w["c_rels"], caps=w["c_caps"],
        join_order=(0, 1, 2), snapshot_dir=snapshot_dir, policy=policy)


def run_one_round(w, policy=None):
    return resilient_one_round_query(
        w["or_grid"], w["query"], w["or_rels"], caps=w["or_caps"],
        join_order=(0, 1, 2), policy=policy)


def assert_matches(base, got):
    out_b, st_b, ovf_b = base
    out_g, st_g, ovf_g, rep = got
    assert trees_equal(out_b, out_g), "output diverged from fault-free run"
    assert trees_equal(st_b, st_g), "stats diverged from fault-free run"
    assert bool(ovf_b) == bool(ovf_g)
    return rep


# ---------------------------------------------------------------------------
# R1 — the injector itself
# ---------------------------------------------------------------------------

class TestInjector:
    def test_same_seed_same_faults(self):
        specs = [FaultSpec("shuffle", "crash", 0.5),
                 FaultSpec("shuffle", "delay", 0.3, delay_ms=0.0)]

        def drive(inj):
            log = []
            for _ in range(64):
                try:
                    inj("shuffle", None)
                    log.append("ok")
                except InjectedCrash:
                    log.append("crash")
            return log, dict(inj.fired)

        log_a, fired_a = drive(FaultInjector(specs, seed=CHAOS_SEED))
        log_b, fired_b = drive(FaultInjector(specs, seed=CHAOS_SEED))
        assert log_a == log_b and fired_a == fired_b
        assert fired_a[("shuffle", "crash")] > 0
        log_c, _ = drive(FaultInjector(specs, seed=CHAOS_SEED + 1))
        assert log_c != log_a, "different seed must replay differently"

    def test_tracer_calls_never_fire_or_consume_rng(self):
        inj = FaultInjector([FaultSpec("shuffle", "crash", 1.0)], seed=0)

        @jax.jit
        def f(x):
            return inj("shuffle", x) + 1

        assert int(f(jnp.zeros(()))) == 1          # traced: no fault baked in
        assert inj.observed["shuffle"] == 0        # and no RNG consumed
        with pytest.raises(InjectedCrash):
            inj("shuffle", np.zeros(2))            # eager: fires

    def test_kill_switch_and_arming_delay(self):
        inj = FaultInjector([FaultSpec("shuffle", "crash", 1.0,
                                       max_fires=1, skip_first=2)], seed=0)
        outcomes = []
        for _ in range(5):
            try:
                inj("shuffle", None)
                outcomes.append("ok")
            except InjectedCrash:
                outcomes.append("crash")
        assert outcomes == ["ok", "ok", "crash", "ok", "ok"]

    def test_install_restores_clean_hooks(self):
        from repro.checkpoint import store as ckpt_store
        from repro.core import shuffle as shuffle_mod
        from repro.serving import engine as engine_mod
        inj = FaultInjector([], seed=0)
        with inj:
            assert shuffle_mod._fault_hook is inj
            assert ckpt_store._fault_hook is inj
            assert engine_mod._fault_hook is inj
            assert faults_mod.active_injector() is inj
        assert shuffle_mod._fault_hook is None
        assert ckpt_store._fault_hook is None
        assert engine_mod._fault_hook is None
        assert faults_mod.active_injector() is None

    def test_corruption_is_always_detected(self):
        inj = FaultInjector([FaultSpec("partition_read", "corrupt", 1.0)],
                            seed=0)
        a = np.arange(8, dtype=np.int32)
        damaged = inj("partition_read", a)
        assert damaged.shape == a.shape and not np.array_equal(damaged, a)
        # payloads without caller-side CRCs surface as DataCorrupt
        inj2 = FaultInjector([FaultSpec("submit", "corrupt", 1.0)], seed=0)
        with pytest.raises(DataCorrupt):
            inj2("submit", object())

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            FaultSpec("nowhere", "crash", 0.5)
        with pytest.raises(ValueError):
            FaultSpec("shuffle", "explode", 0.5)
        with pytest.raises(ValueError):
            FaultSpec("shuffle", "crash", 1.5)
        with pytest.raises(ValueError):
            FaultSpec("shuffle", "crash", 0.5, skip_first=-1)


# ---------------------------------------------------------------------------
# R2 — torn checkpoints are skipped
# ---------------------------------------------------------------------------

class TestTornCheckpoints:
    def test_latest_step_skips_torn(self, tmp_path):
        tree = {"a": jnp.arange(4, dtype=jnp.float32)}
        save(str(tmp_path), 0, tree)
        path1 = save(str(tmp_path), 1, tree)
        npz = os.path.join(path1, "arrays.npz")
        raw = bytearray(open(npz, "rb").read())
        raw[-5] ^= 0xFF
        open(npz, "wb").write(bytes(raw))
        assert latest_step(str(tmp_path)) == 0     # torn step 1 skipped
        os.remove(npz)
        assert latest_step(str(tmp_path)) == 0     # half-written: skipped too
        assert latest_step(str(tmp_path), verify=False) == 0

    def test_latest_hop_skips_torn(self, tmp_path, chain3):
        rel = chain3["c_rels"][0]
        save_hop(str(tmp_path), 0, rel, {"hop": 0})
        path1 = save_hop(str(tmp_path), 1, rel, {"hop": 1})
        _rot_hop_npz(path1)
        assert latest_hop(str(tmp_path)) == 0


# ---------------------------------------------------------------------------
# R3 — cascade recovery
# ---------------------------------------------------------------------------

class TestCascadeRecovery:
    def test_fault_free_bitwise_identical(self, chain3):
        rep = assert_matches(chain3["base_c"], run_cascade(chain3))
        assert rep.retries == 0 and rep.resumed_from is None

    def test_crash_storm_recovers_bitwise(self, chain3):
        with FaultInjector([FaultSpec("shuffle", "crash", 0.3)],
                           seed=CHAOS_SEED) as inj:
            got = run_cascade(chain3)
        rep = assert_matches(chain3["base_c"], got)
        if inj.fired[("shuffle", "crash")]:
            assert rep.retries == inj.fired[("shuffle", "crash")]
            assert rep.recovery_total > 0

    def test_killed_process_resumes_from_snapshot(self, chain3, tmp_path):
        snap = str(tmp_path / "hops")
        # Arm after hop_0's two shuffle opportunities: hop_1 dies every
        # attempt, but hop_0's snapshot survives the "process".
        with FaultInjector([FaultSpec("shuffle", "crash", 1.0,
                                      skip_first=2)], seed=CHAOS_SEED):
            with pytest.raises(HopFailed) as ei:
                run_cascade(chain3, snapshot_dir=snap)
        assert ei.value.where == "hop_1"
        assert latest_hop(snap) == 0               # the materialized lineage

        got = run_cascade(chain3, snapshot_dir=snap)   # the restarted process
        rep = assert_matches(chain3["base_c"], got)
        assert rep.resumed_from == 0 and rep.retries == 0

    def test_corrupt_snapshot_quarantined(self, chain3, tmp_path):
        snap = str(tmp_path / "hops")
        out, st, ovf, rep = run_cascade(chain3, snapshot_dir=snap)
        assert rep.snapshots_written == 1
        _rot_hop_npz(os.path.join(snap, "step_0"))

        got = run_cascade(chain3, snapshot_dir=snap)
        rep2 = assert_matches(chain3["base_c"], got)
        assert rep2.resumed_from is None           # never resumed from rot
        assert any("step_0" in q for q in rep2.quarantined)

    def test_retry_budget_exhaustion_is_typed(self, chain3):
        policy = RecoveryPolicy(max_attempts=2, backoff_base_ms=0.0)
        with FaultInjector([FaultSpec("shuffle", "crash", 1.0)],
                           seed=CHAOS_SEED):
            with pytest.raises(HopFailed) as ei:
                run_cascade(chain3, policy=policy)
        assert ei.value.attempts == 2
        assert isinstance(ei.value.last, InjectedCrash)


# ---------------------------------------------------------------------------
# R4 — one-round recovery
# ---------------------------------------------------------------------------

class TestOneRoundRecovery:
    def test_fault_free_bitwise_identical(self, chain3):
        rep = assert_matches(chain3["base_or"], run_one_round(chain3))
        assert rep.retries == 0 and rep.failed_reducers == 0

    def test_failed_reducers_splice_bitwise(self, chain3):
        with FaultInjector([FaultSpec("reducer", "crash", 0.3)],
                           seed=CHAOS_SEED) as inj:
            got = run_one_round(chain3)
        rep = assert_matches(chain3["base_or"], got)
        assert rep.failed_reducers == inj.fired[("reducer", "crash")]
        if rep.failed_reducers:
            assert rep.recovery_read > 0           # re-read resident shards

    def test_placement_crash_retried(self, chain3):
        with FaultInjector([FaultSpec("shuffle", "crash", 1.0,
                                      max_fires=1)], seed=CHAOS_SEED) as inj:
            got = run_one_round(chain3)
        rep = assert_matches(chain3["base_or"], got)
        assert inj.fired[("shuffle", "crash")] == 1
        assert rep.retries == 1


# ---------------------------------------------------------------------------
# R5 — partition reads
# ---------------------------------------------------------------------------

class TestPartitionRead:
    @pytest.fixture()
    def stored(self, tmp_path):
        rng = np.random.default_rng(3)
        rel = edge_relation(rng.integers(0, 30, 64).astype(np.int32),
                            rng.integers(0, 30, 64).astype(np.int32))
        prel, _ = partition_relation(rel, "a", K, salt=1)
        save_partitioned(str(tmp_path), "edges", prel)
        return str(tmp_path), prel

    def test_corrupt_read_retried_bitwise(self, stored):
        d, prel = stored
        with FaultInjector([FaultSpec("partition_read", "corrupt", 1.0,
                                      max_fires=2)], seed=CHAOS_SEED) as inj:
            got = resilient_load_partitioned(d, "edges")
        assert inj.fired[("partition_read", "corrupt")] == 2
        assert trees_equal(got.parts, prel.parts)

    def test_exhaustion_quarantines(self, stored):
        d, _ = stored
        from repro.resilience.recovery import RecoveryReport
        report = RecoveryReport(strategy="partition_read")
        policy = RecoveryPolicy(max_attempts=2, backoff_base_ms=0.0)
        with FaultInjector([FaultSpec("partition_read", "crash", 1.0)],
                           seed=CHAOS_SEED):
            with pytest.raises(HopFailed):
                resilient_load_partitioned(d, "edges", policy=policy,
                                           report=report)
        assert report.quarantined == [os.path.join(d, "edges")]

    def test_layout_audit_above_crcs(self, stored):
        _, prel = stored
        assert verify_partition_layout(prel)
        # same bytes, wrong claim: a foreign salt proves nothing
        lying = dataclasses.replace(
            prel, spec=dataclasses.replace(prel.spec, salt=7))
        assert not verify_partition_layout(lying)


# ---------------------------------------------------------------------------
# R6 — serving admission control
# ---------------------------------------------------------------------------

def _req(seed=7):
    q = JoinQuery.triangle()
    rng = np.random.default_rng(seed)
    e = (rng.integers(0, 12, 40), rng.integers(0, 12, 40))
    tables = [e] * 3
    return QueryRequest(q, tables, stats=query_stats_exact(q, tables))


class TestAdmissionControl:
    def test_queue_bound_sheds_typed(self):
        eng = QueryEngine(QueryServeConfig(k=K, max_queue=1))
        res = eng.submit_many([_req(1), _req(1), _req(1)])
        assert res[0].ok
        assert [r.error_kind for r in res[1:]] == ["shed", "shed"]
        assert eng.stats.shed == 2 and all(r.output is None for r in res[1:])

    def test_deadline_is_typed_never_late(self):
        eng = QueryEngine(QueryServeConfig(k=K))
        res = eng.submit_many([dataclasses.replace(_req(2),
                                                   deadline_ms=1e-6)])[0]
        assert not res.ok and res.error_kind == "deadline"
        assert res.output is None
        assert eng.stats.deadline_exceeded == 1

    def test_slo_shedding_with_probe_trickle(self):
        eng = QueryEngine(QueryServeConfig(k=K, slo_ms=1e-3, shed_window=4))
        for s in range(4):                 # fill the latency window
            assert eng.submit_many([_req(10 + s)])[0].ok
        res = eng.submit_many([_req(20 + i) for i in range(4)])
        kinds = [r.error_kind for r in res]
        assert kinds.count("shed") == 3 and kinds.count(None) == 1
        assert res[-1].ok                  # the shed_window-th probe lands

    def test_submit_fault_retried_within_budget(self):
        eng = QueryEngine(QueryServeConfig(k=K, submit_retries=2))
        with FaultInjector([FaultSpec("submit", "crash", 1.0, max_fires=2)],
                           seed=CHAOS_SEED):
            res = eng.submit_many([_req(3)])[0]
        assert res.ok and eng.stats.fault_retries == 2

    def test_submit_fault_exhaustion_is_typed(self):
        eng = QueryEngine(QueryServeConfig(k=K, submit_retries=1))
        with FaultInjector([FaultSpec("submit", "corrupt", 1.0)],
                           seed=CHAOS_SEED):
            res = eng.submit_many([_req(4)])[0]
        assert not res.ok and res.error_kind == "fault"


class TestCircuitBreaker:
    def _bad_req(self):
        # ChainStats without a certificate: _build_entry raises, every
        # distinct seed is a fresh cache miss.
        self._seed = getattr(self, "_seed", 100) + 1
        q = JoinQuery.triangle()
        rng = np.random.default_rng(self._seed)
        e = (rng.integers(0, 12, 40), rng.integers(0, 12, 40))
        return QueryRequest(q, [e] * 3,
                            stats=chain_stats_exact([e] * 3))

    def test_opens_after_threshold_hits_still_serve(self):
        eng = QueryEngine(QueryServeConfig(k=K, breaker_threshold=2,
                                           breaker_cooldown=3))
        good = _req(5)
        assert eng.submit_many([good])[0].ok          # primed entry
        for _ in range(2):
            r = eng.submit_many([self._bad_req()])[0]
            assert not r.ok and r.error_kind == "error"
        # breaker open: fresh misses fail fast as typed CircuitOpen
        r = eng.submit_many([_req(6)])[0]
        assert not r.ok and r.error_kind == "circuit"
        assert eng.stats.circuit_open == 1
        # ... but cache hits still serve
        hit = eng.submit_many([good])[0]
        assert hit.ok and hit.cache_hit

    def test_half_open_probe_closes_on_success(self):
        eng = QueryEngine(QueryServeConfig(k=K, breaker_threshold=1,
                                           breaker_cooldown=2))
        assert not eng.submit_many([self._bad_req()])[0].ok
        kinds = [eng.submit_many([_req(30 + i)])[0].error_kind
                 for i in range(2)]
        assert kinds == ["circuit", "circuit"]        # cooldown fast-fails
        probe = eng.submit_many([_req(40)])[0]        # half-open probe
        assert probe.ok
        assert eng.submit_many([_req(41)])[0].ok      # breaker closed


# ---------------------------------------------------------------------------
# R7 — graceful degradation
# ---------------------------------------------------------------------------

def _partitioned_chain(seed, P=K, salt=1):
    cq = ChainQuery.chain(3)
    rng = np.random.default_rng(seed)
    edges = [(rng.integers(0, 16, 50).astype(np.int32),
              rng.integers(0, 16, 50).astype(np.int32)) for _ in range(3)]
    prels, specs = [], []
    for j, (s, d) in enumerate(edges):
        key = cq.attrs[1] if j == 0 else cq.attrs[j]
        rel = edge_relation(s, d, names=cq.schema(j))
        prel, _ = partition_relation(rel, key, P, salt=salt)
        prels.append(prel)
        specs.append(prel.spec)
    return cq, edges, chain_stats_exact(edges), prels, specs


class TestDegradation:
    def test_stale_certificate_serves_exact_via_cascade(self):
        cq, edges, cstats, prels, specs = _partitioned_chain(8)
        cert = chain_partitioning(cq, specs)
        eng = QueryEngine(QueryServeConfig(k=K))

        fresh = eng.submit(cq, rels=prels, stats=cstats, strategy="mapside",
                           partitioning=cert)
        assert fresh.ok and fresh.degraded is None
        assert fresh.plan.strategy == "mapside"

        # The same stored layout under a certificate minted by another
        # key-dtype configuration: proves nothing here, so the engine
        # degrades to the shuffle cascade instead of failing.
        stale = dataclasses.replace(cert, key_dtype="int64")
        res = eng.submit(cq, rels=prels, stats=cstats, strategy="mapside",
                         partitioning=stale)
        assert res.ok and res.degraded == "stale_certificate"
        assert res.plan.strategy == "cascade"
        assert eng.stats.degraded == 1
        n_fresh = float(jnp.sum(fresh.output.valid))
        n_stale = float(jnp.sum(res.output.valid))
        assert n_fresh == n_stale                  # exact, just slower

    def test_delta_failure_falls_back_to_recompute(self, tmp_path):
        eng = QueryEngine(QueryServeConfig(k=K))
        rng = np.random.default_rng(9)
        seen = set()
        while len(seen) < 40:
            seen.add((int(rng.integers(0, 12)), int(rng.integers(0, 12))))
        arr = np.array(sorted(seen))
        store = ServingStore(str(tmp_path), eng, num_partitions=K,
                             drift_threshold=None, delta_capacity=16)
        store.register_aggregate("tri", "cycle", 3)
        store.load_edges(arr[:, 0], arr[:, 1])

        ins = np.array([[0, 1], [2, 3], [4, 5]])
        # submit_retries=2 => 3 attempts; exactly the first delta-term
        # submit exhausts, the recompute fallback's own submits succeed
        with FaultInjector([FaultSpec("submit", "corrupt", 1.0,
                                      max_fires=3)], seed=CHAOS_SEED):
            rep = store.apply_deltas(inserts=(ins[:, 0], ins[:, 1]))
        a = rep["aggregates"]["tri"]
        assert a["mode"] == "recompute_fallback"
        want = float(oracle_triangles(store.src, store.dst))
        assert store.aggregates["tri"].value == pytest.approx(want,
                                                              rel=1e-9)
        assert eng.stats.degraded == 1

    def test_permanent_failure_leaves_store_unchanged(self, tmp_path):
        from repro.serving import IngestError
        eng = QueryEngine(QueryServeConfig(k=K))
        rng = np.random.default_rng(9)
        src = rng.integers(0, 12, 40)
        dst = rng.integers(0, 12, 40)
        store = ServingStore(str(tmp_path), eng, num_partitions=K,
                             drift_threshold=None, delta_capacity=16)
        store.register_aggregate("tri", "cycle", 3)
        store.load_edges(src, dst)
        v0, val0 = store.version, store.aggregates["tri"].value

        with FaultInjector([FaultSpec("submit", "corrupt", 1.0)],
                           seed=CHAOS_SEED):
            with pytest.raises(IngestError):
                store.apply_deltas(inserts=(np.array([0]), np.array([1])))
        assert store.version == v0
        assert store.aggregates["tri"].value == val0

    def test_gc_killed_mid_delete_completed_on_next_open(self, tmp_path,
                                                         monkeypatch):
        eng = QueryEngine(QueryServeConfig(k=K))
        rng = np.random.default_rng(9)
        store = ServingStore(str(tmp_path), eng, num_partitions=K,
                             drift_threshold=None, delta_capacity=16)
        store.load_edges(rng.integers(0, 12, 40), rng.integers(0, 12, 40))
        assert store.version == 1

        # Kill the sweep between the manifest tombstone and the rmtree.
        import repro.serving.store as store_mod

        def boom(path, **kw):
            raise OSError("killed mid-delete")

        monkeypatch.setattr(store_mod.shutil, "rmtree", boom)
        store.apply_deltas(inserts=(np.array([0]), np.array([1])))
        monkeypatch.undo()
        assert store.version == 2
        orphan = tmp_path / "edges_v1"
        assert orphan.is_dir()                       # dir survived the kill
        assert not (orphan / "manifest.json").exists()   # but is tombstoned

        # Next open restores the current version AND completes the sweep.
        store2 = ServingStore(str(tmp_path), eng, num_partitions=K,
                              drift_threshold=None, delta_capacity=16)
        assert store2.version == 2 and store2.n_edges == store.n_edges
        assert not orphan.exists()


# ---------------------------------------------------------------------------
# R8 — the chaos matrix
# ---------------------------------------------------------------------------

class TestChaosMatrix:
    """Exact equality or typed error, across every (kind, site) cell."""

    @pytest.mark.parametrize("kind", ["crash", "delay", "corrupt"])
    def test_shuffle_site(self, chain3, kind):
        spec = FaultSpec("shuffle", kind, 0.3, delay_ms=0.1)
        try:
            with FaultInjector([spec], seed=CHAOS_SEED):
                got = run_cascade(chain3)
        except HopFailed:
            return                                   # typed, never wrong
        assert_matches(chain3["base_c"], got)

    @pytest.mark.parametrize("kind", ["crash", "delay", "corrupt"])
    def test_partition_read_site(self, tmp_path, kind):
        rng = np.random.default_rng(3)
        rel = edge_relation(rng.integers(0, 30, 64).astype(np.int32),
                            rng.integers(0, 30, 64).astype(np.int32))
        prel, _ = partition_relation(rel, "a", K, salt=1)
        save_partitioned(str(tmp_path), "edges", prel)
        spec = FaultSpec("partition_read", kind, 0.5, delay_ms=0.1)
        try:
            with FaultInjector([spec], seed=CHAOS_SEED):
                got = resilient_load_partitioned(str(tmp_path), "edges")
        except HopFailed:
            return
        assert trees_equal(got.parts, prel.parts)

    @pytest.mark.parametrize("kind", ["crash", "delay", "corrupt"])
    def test_submit_site(self, kind):
        eng = QueryEngine(QueryServeConfig(k=K, submit_retries=2))
        base = QueryEngine(QueryServeConfig(k=K)).submit_many([_req(50)])[0]
        assert base.ok
        spec = FaultSpec("submit", kind, 0.5, delay_ms=0.1)
        with FaultInjector([spec], seed=CHAOS_SEED):
            res = eng.submit_many([_req(50)])[0]
        if res.ok:
            assert trees_equal(res.output, base.output)
            assert res.measured == base.measured
        else:
            assert res.error_kind in ("fault", "deadline")
            assert res.output is None
