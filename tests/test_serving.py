"""Serving battery: plan/executable cache, batched multi-tenant
execution, streaming ingest, and fault injection.

Deterministic (always-run, tier-1) counterpart of the hypothesis sweep
in ``tests/test_serving_properties.py``:

  S1  cache-key discipline — byte-identical resubmission HITS; every
      option flip (caps, stats signature, strategy, join order,
      partitioning certificate, key dtype, k, join_impl) MISSES
      (mirrors the jit-cache flip enumeration in test_jaxpr_audit.py)
  S2  LRU semantics — bounded size, eviction order, touch-refreshes
  S3  batching — same-program same-shape tenants run as ONE vmapped
      execution with per-lane answers/stats; a poisoned request or an
      overflowing lane fails alone
  S4  delta maintenance — triangle and path counts stay exactly equal
      to full recomputation under insert-only and mixed streams
  S5  fault injection — a batch failing mid-apply (validation error or
      injected persistence crash) leaves stored partitions and standing
      aggregates unchanged, in memory and on disk
  S6  the LM engine's generate() contract (n_new=0, KV-cache bounds)
  S7  x64 acceptance in a subprocess (key dtype keys the cache)
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (ChainCaps, ChainQuery, JoinQuery, chain_partitioning,
                        chain_stats_exact, edge_relation, oracle_triangles,
                        partition_relation, query_stats_exact,
                        scatter_to_grid)
from repro.serving import (IngestError, QueryEngine, QueryRequest,
                           QueryServeConfig, ServingStore, delta_terms,
                           stats_signature, weighted_total)
from repro.serving.store import META_NAME


def _edges(seed, n_nodes=12, m=60):
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_nodes, m), rng.integers(0, n_nodes, m)


def _uniq_edges(seed, n_nodes=14, m=70):
    rng = np.random.default_rng(seed)
    seen = set()
    while len(seen) < m:
        seen.add((int(rng.integers(0, n_nodes)),
                  int(rng.integers(0, n_nodes))))
    arr = np.array(sorted(seen))
    return arr[:, 0], arr[:, 1]


@pytest.fixture(scope="module")
def engine():
    return QueryEngine(QueryServeConfig(k=4, cache_capacity=64))


# ---------------------------------------------------------------------------
# S1 — cache-key discipline
# ---------------------------------------------------------------------------

class TestCacheKey:
    def setup_method(self):
        self.eng = QueryEngine(QueryServeConfig(k=4, quantize_caps=False))
        self.q = JoinQuery.triangle()
        self.stats = query_stats_exact(self.q, [_edges(0)] * 3)

    def test_identical_resubmission_hits(self):
        k1 = self.eng.cache_key(self.q, self.stats)
        k2 = self.eng.cache_key(self.q, self.stats)
        assert k1 == k2
        # distinct stats objects with equal numbers share the signature
        other = query_stats_exact(self.q, [_edges(0)] * 3)
        assert stats_signature(other) == stats_signature(self.stats)
        assert self.eng.cache_key(self.q, other) == k1

    def test_every_flip_misses(self):
        base = self.eng.cache_key(self.q, self.stats)
        caps = ChainCaps(recv=64, mid=128, out=256)
        part = chain_partitioning(
            ChainQuery.chain(3),
            [partition_relation(
                edge_relation(*_edges(0),
                              names=ChainQuery.chain(3).schema(j)),
                ChainQuery.chain(3).attrs[1] if j == 0
                else ChainQuery.chain(3).attrs[j], 4, salt=1)[0].spec
             for j in range(3)])
        flips = {
            "caps": self.eng.cache_key(self.q, self.stats, caps),
            "stats": self.eng.cache_key(
                self.q, query_stats_exact(self.q, [_edges(1)] * 3)),
            "strategy": self.eng.cache_key(self.q, self.stats,
                                           strategy="one_round"),
            "join_order": self.eng.cache_key(self.q, self.stats,
                                             join_order=(2, 1, 0)),
            "partitioning": self.eng.cache_key(self.q, self.stats,
                                               partitioning=part),
            "key_dtype": self.eng.cache_key(self.q, self.stats,
                                            key_dtype="int64"),
            "query": self.eng.cache_key(JoinQuery.cycle(4), self.stats),
        }
        for name, key in flips.items():
            assert key != base, f"flipping {name} must change the cache key"
        # engine-config axes: k and join_impl are part of the key too
        assert QueryEngine(QueryServeConfig(k=8, quantize_caps=False)) \
            .cache_key(self.q, self.stats) != base
        assert QueryEngine(QueryServeConfig(
            k=4, join_impl="all_pairs", quantize_caps=False)) \
            .cache_key(self.q, self.stats) != base

    def test_salt_rotation_changes_key(self):
        """A certificate minted against a superseded store version
        (different salt) can never hit the old entry."""
        cq = ChainQuery.chain(3)

        def cert(salt):
            return chain_partitioning(cq, [
                partition_relation(
                    edge_relation(*_edges(0), names=cq.schema(j)),
                    cq.attrs[1] if j == 0 else cq.attrs[j], 4,
                    salt=salt)[0].spec
                for j in range(3)])

        k1 = self.eng.cache_key(self.q, self.stats, partitioning=cert(1))
        k2 = self.eng.cache_key(self.q, self.stats, partitioning=cert(2))
        assert k1 != k2

    def test_live_hit_and_miss(self, engine):
        q = JoinQuery.triangle()
        tables = [_edges(7)] * 3
        r1 = engine.submit(q, tables)
        r2 = engine.submit(q, tables)
        assert r1.ok and r2.ok
        assert not r1.cache_hit and r2.cache_hit
        r3 = engine.submit(q, [_edges(8)] * 3)     # different stats
        assert r3.ok and not r3.cache_hit


# ---------------------------------------------------------------------------
# S2 — LRU semantics
# ---------------------------------------------------------------------------

class TestLRU:
    def _submit(self, eng, seed):
        q = JoinQuery.triangle()
        return eng.submit(q, [_edges(seed)] * 3,
                          caps=ChainCaps(recv=256, mid=512, out=1024),
                          strategy="cascade", join_order=(0, 1, 2))

    def test_bounded_size_and_eviction_order(self):
        eng = QueryEngine(QueryServeConfig(k=4, cache_capacity=2))
        ra = self._submit(eng, 0)
        rb = self._submit(eng, 1)
        assert len(eng) == 2 and eng.stats.evictions == 0
        # touch A: it becomes most-recent, so B is next to go
        assert self._submit(eng, 0).cache_hit
        rc = self._submit(eng, 2)
        assert rc.ok and len(eng) == 2 and eng.stats.evictions == 1
        assert self._submit(eng, 0).cache_hit       # A survived
        assert not self._submit(eng, 1).cache_hit   # B was evicted
        assert len(eng) == 2                        # bound holds under churn

    def test_churn_never_exceeds_capacity(self):
        eng = QueryEngine(QueryServeConfig(k=4, cache_capacity=2))
        for seed in range(5):
            assert self._submit(eng, seed).ok
            assert len(eng) <= 2
        assert eng.stats.evictions == 3


# ---------------------------------------------------------------------------
# S3 — batched multi-tenant execution
# ---------------------------------------------------------------------------

class TestBatching:
    def test_one_vmapped_execution_per_shape(self, engine):
        q = JoinQuery.triangle()
        reqs = [QueryRequest(q, [_edges(100 + s)] * 3) for s in range(4)]
        before = engine.stats.batches
        results = engine.submit_many(reqs)
        assert engine.stats.batches == before + 1   # ONE vmapped run
        for s, res in enumerate(results):
            assert res.ok
            got = weighted_total(q, res.output) / 3
            want = oracle_triangles(*_edges(100 + s))
            assert got == pytest.approx(want)
        # resubmission of the whole batch: all hits, still one batch
        again = engine.submit_many(reqs)
        assert all(r.cache_hit for r in again)

    def test_poisoned_request_fails_alone(self, engine):
        q = JoinQuery.triangle()
        good = [QueryRequest(q, [_edges(100 + s)] * 3) for s in range(2)]
        bad = QueryRequest(q, [(np.arange(4),)] * 3)     # wrong arity
        results = engine.submit_many([good[0], bad, good[1]])
        assert [r.ok for r in results] == [True, False, True]
        assert "ValueError" in results[1].error
        for s, res in zip((100, 101), (results[0], results[2])):
            assert weighted_total(q, res.output) / 3 == \
                pytest.approx(oracle_triangles(*_edges(s)))

    def test_overflowing_lane_fails_alone(self, engine):
        q = JoinQuery.triangle()
        tiny = ChainCaps(recv=4, mid=4, out=4)
        reqs = [QueryRequest(q, [_edges(100)] * 3),
                QueryRequest(q, [_edges(101)] * 3, caps=tiny)]
        results = engine.submit_many(reqs)
        assert results[0].ok
        assert not results[1].ok and results[1].overflow
        assert "overflow" in results[1].error

    def test_per_lane_stats_are_exact(self, engine):
        """measured == analytic per tenant: each lane's counted tuples
        equal the cascade cost formula on ITS OWN statistics."""
        from repro.core import cost_query_cascade
        q = JoinQuery.triangle()
        reqs, want = [], []
        for s in range(3):
            tables = [_edges(200 + s)] * 3
            stats = query_stats_exact(q, tables)
            reqs.append(QueryRequest(q, tables, stats=stats,
                                     strategy="cascade",
                                     join_order=(0, 1, 2)))
            idx = stats.orders.index((0, 1, 2))
            want.append(cost_query_cascade(
                [stats.sizes[i] for i in (0, 1, 2)],
                stats.intermediates[idx]))
        results = engine.submit_many(reqs)
        for res, analytic in zip(results, want):
            assert res.ok
            assert res.measured["total"] == pytest.approx(analytic)


# ---------------------------------------------------------------------------
# S4 — delta maintenance == recompute (deterministic sweep)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def store_engine():
    return QueryEngine(QueryServeConfig(k=4, cache_capacity=64))


class TestDeltaMaintenance:
    def _stream(self, tmp_path, store_engine, kind, n, seed):
        src, dst = _uniq_edges(seed)
        store = ServingStore(str(tmp_path), store_engine, num_partitions=4,
                             drift_threshold=None, delta_capacity=16)
        store.register_aggregate("agg", kind, n)
        store.load_edges(src, dst)
        assert store.aggregates["agg"].value == \
            pytest.approx(store.analytic_value("agg"))
        rng = np.random.default_rng(seed + 1000)
        for step in range(3):
            cur = set(zip(store.src.tolist(), store.dst.tolist()))
            ins = []
            while len(ins) < 4:
                e = (int(rng.integers(0, 14)), int(rng.integers(0, 14)))
                if e not in cur and e not in ins:
                    ins.append(e)
            dels = []
            if step > 0:  # mixed stream after the first batch
                pick = rng.choice(store.n_edges, size=3, replace=False)
                dels = [(int(store.src[i]), int(store.dst[i])) for i in pick]
            rep = store.apply_deltas(
                inserts=(np.array([a for a, b in ins]),
                         np.array([b for a, b in ins])),
                deletes=None if not dels else
                        (np.array([a for a, b in dels]),
                         np.array([b for a, b in dels])))
            assert rep["aggregates"]["agg"]["mode"] == "delta"
            assert store.aggregates["agg"].value == \
                pytest.approx(store.analytic_value("agg")), \
                f"{kind} drifted at step {step}"
        return store

    @pytest.mark.parametrize("seed", [0, 1])
    def test_triangle_count_stays_exact(self, tmp_path, store_engine, seed):
        store = self._stream(tmp_path, store_engine, "cycle", 3, seed)
        assert store.aggregates["agg"].value == \
            pytest.approx(oracle_triangles(store.src, store.dst))

    def test_path_count_stays_exact(self, tmp_path, store_engine):
        self._stream(tmp_path, store_engine, "chain", 3, 2)

    def test_delta_moves_fewer_tuples_than_recompute(self, tmp_path,
                                                     store_engine):
        store = self._stream(tmp_path, store_engine, "cycle", 3, 3)
        agg = store.aggregates["agg"]
        # exclude the initial full load (counted in both columns)
        assert agg.delta_tuples < agg.recompute_tuples

    def test_triangle_term_collapse(self):
        """The cyclic expansion uses 3 terms with coefficients 3,3,1;
        a chain needs all 2^n - 1 unit-coefficient terms."""
        tri = delta_terms("cycle", 3)
        assert [c for _, c in tri] == [3.0, 3.0, 1.0]
        chain = delta_terms("chain", 3)
        assert len(chain) == 7 and all(c == 1.0 for _, c in chain)
        assert delta_terms("cycle", 4) == delta_terms("chain", 4)

    def test_drift_threshold_forces_recompute(self, tmp_path, store_engine):
        src, dst = _uniq_edges(5)
        store = ServingStore(str(tmp_path), store_engine, num_partitions=4,
                             drift_threshold=0.05, delta_capacity=16)
        store.register_aggregate("tri", "cycle", 3)
        store.load_edges(src, dst)
        refreshes0 = store.aggregates["tri"].refreshes
        cur = set(zip(src.tolist(), dst.tolist()))
        ins = [(a, b) for a in range(14) for b in range(14)
               if (a, b) not in cur][:8]          # > 5% of 70 edges
        rep = store.apply_deltas(inserts=(np.array([a for a, b in ins]),
                                          np.array([b for a, b in ins])))
        assert rep["aggregates"]["tri"]["mode"] == "recompute"
        assert store.aggregates["tri"].refreshes == refreshes0 + 1
        assert store.aggregates["tri"].drift_rows == 0
        assert store.aggregates["tri"].value == \
            pytest.approx(store.analytic_value("tri"))


# ---------------------------------------------------------------------------
# S5 — fault injection: failed ingest leaves the store unchanged
# ---------------------------------------------------------------------------

class TestFaultInjection:
    def _loaded(self, tmp_path, engine):
        src, dst = _uniq_edges(11)
        store = ServingStore(str(tmp_path), engine, num_partitions=4,
                             drift_threshold=None, delta_capacity=16)
        store.register_aggregate("tri", "cycle", 3)
        store.load_edges(src, dst)
        return store

    def _snapshot(self, store):
        return (store.version, store.n_edges,
                sorted(zip(store.src.tolist(), store.dst.tolist())),
                {n: (a.value, a.drift_rows, a.deltas_applied)
                 for n, a in store.aggregates.items()})

    def _assert_unchanged(self, store, snap, store_engine):
        assert self._snapshot(store) == snap
        # disk too: a fresh process sees the committed state
        reloaded = ServingStore(store.directory, store_engine)
        assert self._snapshot(reloaded) == snap

    def test_validation_failure_mid_batch(self, tmp_path, store_engine):
        """A batch whose DELETE names an absent edge aborts atomically
        even when its inserts are fine."""
        store = self._loaded(tmp_path, store_engine)
        snap = self._snapshot(store)
        with pytest.raises(IngestError, match="absent"):
            store.apply_deltas(inserts=(np.array([0]), np.array([1])),
                               deletes=(np.array([999]), np.array([999])))
        self._assert_unchanged(store, snap, store_engine)

    def test_persistence_crash_mid_apply(self, tmp_path, store_engine,
                                         monkeypatch):
        """Injected crash in the partition-write step: all aggregate
        deltas were already computed, nothing may be mutated."""
        store = self._loaded(tmp_path, store_engine)
        snap = self._snapshot(store)
        import repro.serving.store as store_mod

        def boom(*a, **k):
            raise OSError("disk full (injected)")

        monkeypatch.setattr(store_mod, "save_partitioned", boom)
        with pytest.raises(OSError, match="injected"):
            store.apply_deltas(inserts=(np.array([0]), np.array([1])))
        monkeypatch.undo()
        self._assert_unchanged(store, snap, store_engine)
        # and the store still works after the fault clears
        rep = store.apply_deltas(inserts=(np.array([0]), np.array([1])))
        assert rep["aggregates"]["tri"]["mode"] == "delta"
        assert store.aggregates["tri"].value == \
            pytest.approx(store.analytic_value("tri"))

    def test_crash_between_partitions_and_commit_point(self, tmp_path,
                                                       store_engine,
                                                       monkeypatch):
        """Crash AFTER the new version's partitions land but BEFORE the
        metadata swap: the orphaned partitions are invisible — reload
        serves the old version, and a retry commits cleanly."""
        store = self._loaded(tmp_path, store_engine)
        snap = self._snapshot(store)
        import repro.serving.store as store_mod

        def boom(*a, **k):
            raise OSError("power loss (injected)")

        monkeypatch.setattr(store_mod, "save_json_atomic", boom)
        with pytest.raises(OSError, match="injected"):
            store.apply_deltas(inserts=(np.array([2]), np.array([3])))
        monkeypatch.undo()
        # orphan directory exists, but the committed state is the old one
        assert os.path.isdir(os.path.join(store.directory,
                                          f"edges_v{snap[0] + 1}"))
        self._assert_unchanged(store, snap, store_engine)
        rep = store.apply_deltas(inserts=(np.array([2]), np.array([3])))
        assert rep["version"] == snap[0] + 1
        assert store.aggregates["tri"].value == \
            pytest.approx(store.analytic_value("tri"))

    def test_torn_meta_tmp_is_recovered(self, tmp_path, store_engine):
        """A torn ``serving_meta.json.tmp`` (crash mid-write before the
        atomic rename) is ignored on reload."""
        store = self._loaded(tmp_path, store_engine)
        snap = self._snapshot(store)
        with open(os.path.join(store.directory, META_NAME + ".tmp"),
                  "w") as f:
            f.write('{"format": "repro-serving-v1", "vers')  # torn
        self._assert_unchanged(store, snap, store_engine)


# ---------------------------------------------------------------------------
# S6 — the LM engine's generate() contract
# ---------------------------------------------------------------------------

class TestLMGenerate:
    @pytest.fixture(scope="class")
    def lm(self):
        import jax
        from repro.configs import get_config
        from repro.models.lm import build_model
        from repro.serving import Engine, ServeConfig
        cfg = get_config("qwen2-7b", smoke=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        return Engine(model, params, ServeConfig(max_len=16))

    def test_n_new_zero_returns_empty(self, lm):
        prompts = np.ones((2, 4), np.int32)
        out, stats = lm.generate(prompts, 0)
        assert out.shape == (2, 0) and out.dtype == np.int32
        assert stats["generated"] == 0.0 and stats["prompt_len"] == 4.0

    def test_negative_n_new_rejected(self, lm):
        with pytest.raises(ValueError, match="n_new"):
            lm.generate(np.ones((1, 4), np.int32), -1)

    def test_kv_cache_bound_enforced(self, lm):
        with pytest.raises(ValueError, match="max_len"):
            lm.generate(np.ones((1, 10), np.int32), 7)   # 10 + 7 > 16
        out, _ = lm.generate(np.ones((1, 14), np.int32), 2)  # == max_len
        assert out.shape == (1, 2)


# ---------------------------------------------------------------------------
# S7 — x64 acceptance (subprocess: the flag must precede JAX arrays)
# ---------------------------------------------------------------------------

def test_x64_serving_subprocess():
    out = subprocess.run(
        [sys.executable, "tests/_serving_x64_check.py"],
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
