"""Property-based serving tests (hypothesis): incremental maintenance
of standing aggregates equals full recomputation for ARBITRARY delta
streams, not just the curated ones.

  SP1  triangle (cyclic) counts: delta == recompute == host oracle for
       random insert-only streams
  SP2  triangle counts under mixed insert/delete streams
  SP3  chain path counts under mixed streams

The deterministic counterparts (always-run, tier-1, plus the x32/x64
subprocess acceptance) live in ``tests/test_serving.py``; this file
widens the search when hypothesis is installed.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import oracle_triangles  # noqa: E402
from repro.serving import (QueryEngine, QueryServeConfig,  # noqa: E402
                           ServingStore)

SETTINGS = dict(max_examples=8, deadline=None)

#: One engine for the whole module — compiled delta-term programs are
#: reused across examples, which is exactly the serving cache working.
ENGINE = QueryEngine(QueryServeConfig(k=4, cache_capacity=64))

N_NODES = 10


def _unique_edges(rng, m):
    seen = set()
    while len(seen) < m:
        seen.add((int(rng.integers(0, N_NODES)),
                  int(rng.integers(0, N_NODES))))
    arr = np.array(sorted(seen))
    return arr[:, 0], arr[:, 1]


def _stream_store(tmpdir, kind, n, seed, n_batches, with_deletes):
    rng = np.random.default_rng(seed)
    src, dst = _unique_edges(rng, 40)
    store = ServingStore(str(tmpdir), ENGINE, num_partitions=4,
                         drift_threshold=None, delta_capacity=16)
    store.register_aggregate("agg", kind, n)
    store.load_edges(src, dst)
    for _ in range(n_batches):
        cur = set(zip(store.src.tolist(), store.dst.tolist()))
        ins = []
        while len(ins) < int(rng.integers(1, 5)):
            e = (int(rng.integers(0, N_NODES)),
                 int(rng.integers(0, N_NODES)))
            if e not in cur and e not in ins:
                ins.append(e)
        dels = []
        if with_deletes and store.n_edges > 4:
            pick = rng.choice(store.n_edges,
                              size=int(rng.integers(1, 4)), replace=False)
            dels = [(int(store.src[i]), int(store.dst[i])) for i in pick]
        store.apply_deltas(
            inserts=(np.array([a for a, b in ins]),
                     np.array([b for a, b in ins])),
            deletes=None if not dels else
                    (np.array([a for a, b in dels]),
                     np.array([b for a, b in dels])))
        assert store.aggregates["agg"].value == \
            pytest.approx(store.analytic_value("agg"))
    return store


@settings(**SETTINGS)
@given(seed=st.integers(0, 999), n_batches=st.integers(1, 3))
def test_sp1_triangle_insert_only(tmp_path_factory, seed, n_batches):
    d = tmp_path_factory.mktemp("sp1")
    store = _stream_store(d, "cycle", 3, seed, n_batches,
                          with_deletes=False)
    assert store.aggregates["agg"].value == \
        pytest.approx(oracle_triangles(store.src, store.dst))


@settings(**SETTINGS)
@given(seed=st.integers(0, 999), n_batches=st.integers(1, 3))
def test_sp2_triangle_mixed_stream(tmp_path_factory, seed, n_batches):
    d = tmp_path_factory.mktemp("sp2")
    store = _stream_store(d, "cycle", 3, seed, n_batches,
                          with_deletes=True)
    assert store.aggregates["agg"].value == \
        pytest.approx(oracle_triangles(store.src, store.dst))


@settings(**SETTINGS)
@given(seed=st.integers(0, 999))
def test_sp3_chain_paths_mixed_stream(tmp_path_factory, seed):
    d = tmp_path_factory.mktemp("sp3")
    _stream_store(d, "chain", 3, seed, 2, with_deletes=True)
