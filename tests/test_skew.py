"""Skew layer tests: detector → SkewSplit lowering → planner strategy.

* Zipf generator determinism under a fixed seed.
* Heavy-hitter detection is exact (the kernel histogram pre-filter has
  no false negatives, the host pass no false positives).
* Heavy/residual split exactness: the SharesSkew union equals the
  unskewed one-round result (and the aggregated sums match the oracle).
* Measured SharesSkew communication == the analytic cost, exactly, at
  N=3 (read and shuffle separately).
* The planner selects SharesSkew on a Zipf(1.2) three-way chain and
  never selects it on uniform data; the skew path's measured
  ``max_bucket_load`` is strictly lower than plain Shares on the same
  reducer budget.
"""

import numpy as np
import pytest

import jax

from repro.core import (
    ChainCaps, ChainQuery, Relation, SimGrid, balance_threshold,
    chain_edge_inputs, chain_stats_exact, detect_chain_skew, edge_relation,
    heavy_hitters, one_round_chain, plan_chain, shares_skew_chain,
    skew_crossover_scale,
)
from repro.core.skew import chain_key_sketch
from repro.data.graphs import zipf_edges

K = 16
CAPS = ChainCaps(recv=512, mid=8192, out=16384, local=1024, agg=4096,
                 join=16384)


def hot_edges(rng, n_nodes=40, n_edges=72, hot=0.4):
    """Uniform edges with a constructed heavy hitter: key 0 takes a
    ``hot`` fraction of both columns — above the balance threshold
    1.25·r/4 of the (4,4) grid at K=16."""
    src = rng.integers(1, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(1, n_nodes, n_edges).astype(np.int32)
    src[rng.random(n_edges) < hot] = 0
    dst[rng.random(n_edges) < hot] = 0
    return src, dst


def collect_grid_tuples(out: Relation, grid_rank: int, names) -> set:
    flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[grid_rank:]), out)
    got = set()
    for dev in range(flat.valid.shape[0]):
        sub = Relation({k: v[dev] for k, v in flat.cols.items()},
                       flat.valid[dev])
        got |= sub.to_tuple_set(names)
    return got


class TestZipfGenerator:
    def test_deterministic_under_fixed_seed(self):
        a = zipf_edges(200, 500, 1.2, seed=11)
        b = zipf_edges(200, 500, 1.2, seed=11)
        assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
        c = zipf_edges(200, 500, 1.2, seed=12)
        assert not (np.array_equal(a[0], c[0]) and np.array_equal(a[1], c[1]))

    def test_alpha_controls_concentration(self):
        top = {}
        for alpha in (0.0, 1.2):
            _, dst = zipf_edges(500, 2000, alpha, seed=0)
            top[alpha] = np.bincount(dst).max() / len(dst)
        assert top[1.2] > 4 * top[0.0]
        assert top[1.2] > 0.15  # Zipf(1.2) puts ~1/ζ(1.2) on the top key

    def test_rejects_bad_parameters(self):
        with pytest.raises(ValueError):
            zipf_edges(0, 10, 1.0)
        with pytest.raises(ValueError):
            zipf_edges(10, 10, -0.5)


class TestHeavyHitters:
    def test_exact_against_ground_truth(self):
        rng = np.random.default_rng(5)
        vals = np.concatenate([np.full(40, 7), np.full(25, 3),
                               rng.integers(10, 500, 300)]).astype(np.int32)
        rng.shuffle(vals)
        keys, counts = heavy_hitters(vals, threshold=20.0)
        assert keys.tolist() == [7, 3]          # sorted by count, desc
        assert counts.tolist() == [40.0, 25.0]
        # ground truth: every key above threshold found, none below
        u, c = np.unique(vals, return_counts=True)
        assert set(keys.tolist()) == set(u[c > 20].tolist())

    def test_empty_cases(self):
        keys, _ = heavy_hitters(np.arange(100, dtype=np.int32), threshold=5.0)
        assert keys.size == 0
        keys, _ = heavy_hitters(np.empty(0, np.int32), threshold=1.0)
        assert keys.size == 0
        keys, _ = heavy_hitters(np.zeros(50, np.int32),
                                threshold=float("inf"))
        assert keys.size == 0

    def test_balance_threshold(self):
        assert balance_threshold(100.0, 4, slack=1.25) == pytest.approx(31.25)
        assert balance_threshold(100.0, 1) == float("inf")


class TestDetection:
    def test_uniform_detects_nothing(self):
        rng = np.random.default_rng(2)
        edges = [(rng.integers(0, 200, 120).astype(np.int32),
                  rng.integers(0, 200, 120).astype(np.int32))
                 for _ in range(3)]
        assert detect_chain_skew(ChainQuery.three_way(), edges, K) is None

    def test_skewed_plan_shape(self):
        rng = np.random.default_rng(3)
        edges = [hot_edges(rng) for _ in range(3)]
        plan = detect_chain_skew(ChainQuery.three_way(), edges, K)
        assert plan is not None
        assert all(0 in h for h in plan.heavy if h.size)
        # All-residual combination first, on the unclamped base grid.
        assert plan.combos[0].heavy_dims == (False, False)
        assert plan.combos[0].grid_shape == plan.base_shape
        # Heavy dims are clamped to share 1.
        for combo in plan.combos[1:]:
            for d, h in enumerate(combo.heavy_dims):
                assert combo.grid_shape[d] == (1 if h else plan.base_shape[d])
        # Parts partition each relation: over combos that differ only in
        # dims the relation pins, sizes sum to the relation size.
        sizes = np.zeros(3)
        for combo in plan.combos:
            sizes += np.array(combo.sizes)
        # every relation pins ≤ 2 of the 2 dims; with both dims active,
        # rel 0 and 2 are read twice (once per far-dim choice), rel 1 once
        reads = [2.0 ** (2 - len(ChainQuery.three_way().hashed_dims(j)))
                 for j in range(3)]
        for j, mult in enumerate(reads):
            assert sizes[j] == pytest.approx(72.0 * mult)


class TestSkewSplitExecution:
    """Heavy/residual split exactness + measured==analytic at N=3."""

    def setup_method(self, method):
        rng = np.random.default_rng(7)
        self.edges = [hot_edges(rng) for _ in range(3)]
        self.query = ChainQuery.three_way()
        self.plan = detect_chain_skew(self.query, self.edges, K)
        assert self.plan is not None and len(self.plan.combos) >= 3

    def flat_rels(self, query):
        return [edge_relation(s, d, names=query.schema(j))
                for j, (s, d) in enumerate(self.edges)]

    def test_union_equals_unskewed_and_measured_equals_analytic(self):
        out, st, ovf = shares_skew_chain(
            self.query, self.flat_rels(self.query), self.plan, caps=CAPS,
            measure_skew=True)
        assert not bool(ovf)

        grid = SimGrid(self.plan.base_shape)
        rels = chain_edge_inputs(self.query, self.edges, self.plan.base_shape)
        out_p, st_p, ovf_p = one_round_chain(grid, self.query, rels,
                                             caps=CAPS, measure_skew=True)
        assert not bool(ovf_p)

        # Split exactness: the union over combinations is the join.
        expect = collect_grid_tuples(out_p, 2, self.query.attrs)
        assert expect, "degenerate test: empty join"
        assert out.to_tuple_set(self.query.attrs) == expect
        # Acceptance: strictly better balance at equal reducer budget.
        assert float(st["max_bucket_load"]) < float(st_p["max_bucket_load"])
        # Acceptance: measured SharesSkew communication == analytic, exactly.
        assert float(st["read"]) == self.plan.read_cost()
        assert float(st["shuffled"]) == self.plan.shuffle_cost()
        assert float(st["total"]) == self.plan.cost()

    def test_aggregated_union_matches_oracle(self):
        query = ChainQuery.three_way(aggregate=True)
        plan = detect_chain_skew(query, self.edges, K)
        out, st, ovf = shares_skew_chain(query, self.flat_rels(query), plan,
                                         caps=CAPS)
        assert not bool(ovf)
        got = {}
        d = out.to_numpy()
        for a, z, p in zip(d["a"], d["d"], d["p"]):
            got[(int(a), int(z))] = got.get((int(a), int(z)), 0.0) + float(p)

        # Host oracle: brute-force path products.
        oracle = {}
        (s0, d0), (s1, d1), (s2, d2) = self.edges
        for i in range(len(s0)):
            for j in range(len(s1)):
                if d0[i] != s1[j]:
                    continue
                for l in range(len(s2)):
                    if d1[j] != s2[l]:
                        continue
                    key = (int(s0[i]), int(d2[l]))
                    oracle[key] = oracle.get(key, 0.0) + 1.0
        assert set(got) == set(oracle)
        for kk in oracle:
            np.testing.assert_allclose(got[kk], oracle[kk], rtol=1e-5)
        # Aggregated analytic: sub-join comm + 2·Σ|combo join| = 2·j3.
        stats = chain_stats_exact(self.edges)
        assert float(st["total"]) == plan.cost() + 2.0 * stats.prefix_joins[-1]


class TestEmptySkewPlan:
    def test_all_empty_combinations_prove_empty_join(self):
        """R1.dst is a single heavy key that R2.src never contains: every
        combination loses an input, which proves the join is empty — the
        lowering must return an empty relation at zero cost, not crash."""
        rng = np.random.default_rng(9)
        n = 48
        r1 = (rng.integers(1, 30, n).astype(np.int32),
              np.full(n, 5, np.int32))           # dst ≡ heavy key 5
        r2 = (rng.integers(6, 30, n).astype(np.int32),  # src never 5
              rng.integers(0, 30, n).astype(np.int32))
        r3 = (rng.integers(0, 30, n).astype(np.int32),
              rng.integers(0, 30, n).astype(np.int32))
        edges = [r1, r2, r3]
        query = ChainQuery.three_way()
        plan = detect_chain_skew(query, edges, K)
        assert plan is not None and plan.combos == ()
        assert plan.cost() == 0.0
        flat = [edge_relation(s, d, names=query.schema(j))
                for j, (s, d) in enumerate(edges)]
        out, st, ovf = shares_skew_chain(query, flat, plan, caps=CAPS,
                                         measure_skew=True)
        assert not bool(ovf)
        assert out.to_tuple_set(query.attrs) == set()
        assert float(st["total"]) == 0.0
        # The planner prices this plan at 0 — honest: nothing runs.
        stats = chain_stats_exact(edges, sketch_top_k=16)
        assert stats.prefix_joins[-1] == 0.0  # the join really is empty
        chain_plan = plan_chain(stats, K, aggregate=False)
        assert chain_plan.skew_detected


class TestPlannerSkew:
    def test_zipf_selects_shares_skew(self):
        """Acceptance: Zipf(1.2) three-way chain → planner picks 1,3JS."""
        src, dst = zipf_edges(800, 160, 1.2, seed=3)
        stats = chain_stats_exact([(src, dst)] * 3, sketch_top_k=16)
        plan = plan_chain(stats, 64, aggregate=False)
        assert plan.skew_detected
        assert plan.algorithm == "1,3JS"
        assert plan.strategy == "shares_skew"
        assert plan.adjusted_costs["1,3JS"] < plan.adjusted_costs["1,3J"]
        # The sketch marks the workload as already past the crossover.
        assert skew_crossover_scale(stats, 64) <= 1.0

    def test_uniform_never_selects_skew_path(self):
        """Acceptance: uniform data → the plain PR-1 decision, bit-for-bit."""
        src, dst = zipf_edges(800, 160, 0.0, seed=3)
        stats = chain_stats_exact([(src, dst)] * 3, sketch_top_k=16)
        plan = plan_chain(stats, 64, aggregate=False)
        assert not plan.skew_detected
        assert "JS" not in plan.algorithm
        assert plan.adjusted_costs is None
        # Identical choice and costs to planning without any sketch.
        import dataclasses
        bare = plan_chain(dataclasses.replace(stats, key_freqs=None), 64,
                          aggregate=False)
        assert bare.algorithm == plan.algorithm
        assert bare.costs == plan.costs
        assert skew_crossover_scale(stats, 64) > 1.0

    def test_aggregated_skew_candidate(self):
        src, dst = zipf_edges(800, 160, 1.2, seed=3)
        stats = chain_stats_exact([(src, dst)] * 3, sketch_top_k=16)
        plan = plan_chain(stats, 64, aggregate=True)
        assert plan.skew_detected
        assert "1,3JSA" in plan.costs
        assert plan.algorithm in ("1,3JSA", "2,3JA", "1,3JA")
