"""Property-based equivalence suite for the sort-merge data plane.

The fast path must be indistinguishable (as a relation: tuple multiset
+ overflow flag) from the quadratic oracles it replaced:

  D1  sort_merge_join == local_join_allpairs for random key
      distributions incl. duplicates, random invalid masks (up to
      all-invalid), and exact output-capacity overflow boundaries
  D2  the same through the vmapped per-device path (SimGrid
      two_way_join with join_impl on both settings: identical tuple
      sets, stats, and overflow)
  D3  single-pass groupby_sum == multipass oracle: identical keys,
      validity, overflow; sums allclose
  D4  overflow boundary is exact on both join impls: capacity == total
      matches keeps every match with no overflow; capacity - 1 flags

The deterministic counterparts (always-run, no hypothesis) live in
tests/test_data_plane.py.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core import SimGrid, edge_relation, two_way_join
from repro.core.local import (groupby_sum, groupby_sum_multipass,
                              local_join_allpairs, sort_merge_join)
from repro.core.relation import Relation

SETTINGS = dict(max_examples=25, deadline=None)


def make_relation(rng, n_rows, capacity, domain, key_name, val_name,
                  invalid_frac=0.0):
    keys = rng.integers(0, domain, n_rows).astype(np.int32)
    vals = rng.normal(size=n_rows).astype(np.float32)
    rel = Relation.from_arrays(capacity, **{key_name: jnp.array(keys),
                                            val_name: jnp.array(vals)})
    if invalid_frac:
        keep = jnp.array(rng.random(capacity) >= invalid_frac)
        rel = rel.filter(keep)
    return rel


def tuple_multiset(rel, names):
    data = rel.to_numpy()
    return sorted(zip(*[data[n].tolist() for n in names]))


@settings(**SETTINGS)
@given(n_left=st.integers(1, 60), n_right=st.integers(1, 60),
       domain=st.integers(1, 20), pad=st.integers(0, 10),
       out_cap=st.integers(1, 256), invalid=st.floats(0.0, 1.0),
       seed=st.integers(0, 999))
def test_d1_join_equivalence(n_left, n_right, domain, pad, out_cap, invalid,
                             seed):
    """D1: same tuples, same overflow, over duplicates / padding /
    random invalid masks (up to all-invalid)."""
    rng = np.random.default_rng(seed)
    left = make_relation(rng, n_left, n_left + pad, domain, "b", "v", invalid)
    right = make_relation(rng, n_right, n_right + pad, domain, "b", "w",
                          invalid)
    got, ovf_s = sort_merge_join(left, right, "b", "b", out_cap)
    want, ovf_a = local_join_allpairs(left, right, "b", "b", out_cap)
    assert bool(ovf_s) == bool(ovf_a)
    if not bool(ovf_a):
        assert tuple_multiset(got, ("b", "v", "w")) == \
            tuple_multiset(want, ("b", "v", "w"))
    else:
        # under overflow both keep exactly out_cap matches (subsets may
        # differ: key order vs row-major order)
        assert int(got.count()) == int(want.count()) == out_cap


@settings(**SETTINGS)
@given(n=st.integers(1, 40), domain=st.integers(1, 8),
       seed=st.integers(0, 999))
def test_d4_exact_capacity_boundary(n, domain, seed):
    """D4: out_capacity == n_matches is NOT overflow (every match kept);
    out_capacity == n_matches - 1 is."""
    rng = np.random.default_rng(seed)
    left = make_relation(rng, n, n, domain, "b", "v")
    right = make_relation(rng, n, n, domain, "b", "w")
    lk, rk = np.asarray(left.cols["b"]), np.asarray(right.cols["b"])
    n_match = int((lk[:, None] == rk[None, :]).sum())
    if n_match == 0:
        return
    for fn in (sort_merge_join, local_join_allpairs):
        out, ovf = fn(left, right, "b", "b", n_match)
        assert not bool(ovf)
        assert int(out.count()) == n_match
    if n_match > 1:
        for fn in (sort_merge_join, local_join_allpairs):
            _, ovf = fn(left, right, "b", "b", n_match - 1)
            assert bool(ovf)


@settings(max_examples=10, deadline=None)
@given(n_edges=st.integers(5, 50), n_nodes=st.integers(2, 10),
       grid_shape=st.sampled_from([(2,), (4,), (2, 2)]),
       seed=st.integers(0, 999))
def test_d2_vmapped_two_way_join(n_edges, n_nodes, grid_shape, seed):
    """D2: through SimGrid (the vmapped per-device path) both impls give
    identical tuple sets, stats, and overflow."""
    rng = np.random.default_rng(seed)
    a, b = (rng.integers(0, n_nodes, n_edges).astype(np.int32)
            for _ in range(2))
    c, d = (rng.integers(0, n_nodes, n_edges).astype(np.int32)
            for _ in range(2))
    n_dev = int(np.prod(grid_shape))
    per = -(-n_edges // n_dev)

    def scatter(rel):
        pad = per * n_dev - rel.capacity
        cols = {k: jnp.pad(v, (0, pad)).reshape(grid_shape + (per,))
                for k, v in rel.cols.items()}
        return Relation(cols, jnp.pad(rel.valid, (0, pad)).reshape(
            grid_shape + (per,)))

    R = scatter(edge_relation(a, b, names=("a", "b", "v")))
    S = scatter(edge_relation(c, d, names=("b", "c", "w")))
    grid = SimGrid(grid_shape)

    results = {}
    for impl in ("sort_merge", "all_pairs"):
        out, stats, ovf = two_way_join(grid, R, S, "b", "b",
                                       recv_capacity=256, out_capacity=4096,
                                       join_impl=impl)
        assert not bool(ovf)
        flat = jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[len(grid_shape):]), out)
        got = set()
        for dev in range(flat.valid.shape[0]):
            sub = Relation({k: v[dev] for k, v in flat.cols.items()},
                           flat.valid[dev])
            got |= sub.to_tuple_set(("a", "b", "c"))
        results[impl] = (got, {k: float(v) for k, v in stats.items()})
    assert results["sort_merge"] == results["all_pairs"]
    expect = {(int(x), int(y), int(z)) for x, y in zip(a, b)
              for y2, z in zip(c, d) if y == y2}
    assert results["sort_merge"][0] == expect


@settings(**SETTINGS)
@given(n=st.integers(1, 60), pad=st.integers(0, 10),
       domain=st.integers(1, 10), out_cap=st.integers(1, 40),
       invalid=st.floats(0.0, 1.0), seed=st.integers(0, 999))
def test_d3_groupby_equivalence(n, pad, domain, out_cap, invalid, seed):
    """D3: single-pass groupby_sum == multipass oracle (keys, validity,
    overflow bit-identical; sums allclose), incl. overflow boundaries
    and random invalid masks."""
    rng = np.random.default_rng(seed)
    rel = Relation.from_arrays(
        n + pad,
        a=jnp.array(rng.integers(0, domain, n + pad), jnp.int32),
        c=jnp.array(rng.integers(0, domain, n + pad), jnp.int32),
        p=jnp.array(rng.normal(size=n + pad), jnp.float32))
    rel = Relation(rel.cols, jnp.array(rng.random(n + pad) >= invalid)
                   & rel.valid)
    got, ovf_s = groupby_sum(rel, ("a", "c"), "p", out_cap)
    want, ovf_m = groupby_sum_multipass(rel, ("a", "c"), "p", out_cap)
    assert bool(ovf_s) == bool(ovf_m)
    np.testing.assert_array_equal(np.asarray(got.valid),
                                  np.asarray(want.valid))
    for col in ("a", "c"):
        np.testing.assert_array_equal(np.asarray(got.cols[col]),
                                      np.asarray(want.cols[col]))
    np.testing.assert_allclose(np.asarray(got.cols["p"]),
                               np.asarray(want.cols["p"]),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(n=st.integers(1, 40), domain=st.integers(1, 8),
       seed=st.integers(0, 999))
def test_d3_groupby_vmapped(n, domain, seed):
    """D3 on the vmapped per-device path (a stacked batch of reducers)."""
    rng = np.random.default_rng(seed)

    def one(_):
        return Relation.from_arrays(
            n,
            a=jnp.array(rng.integers(0, domain, n), jnp.int32),
            c=jnp.array(rng.integers(0, domain, n), jnp.int32),
            p=jnp.array(rng.normal(size=n), jnp.float32))

    rels = [one(i) for i in range(3)]
    batched = jax.tree.map(lambda *xs: jnp.stack(xs), *rels)
    got, ovf_s = jax.vmap(lambda r: groupby_sum(r, ("a", "c"), "p"))(batched)
    want, ovf_m = jax.vmap(
        lambda r: groupby_sum_multipass(r, ("a", "c"), "p"))(batched)
    np.testing.assert_array_equal(np.asarray(ovf_s), np.asarray(ovf_m))
    np.testing.assert_array_equal(np.asarray(got.valid),
                                  np.asarray(want.valid))
    np.testing.assert_allclose(np.asarray(got.cols["p"]),
                               np.asarray(want.cols["p"]),
                               rtol=1e-5, atol=1e-5)


# The deterministic variants of these invariants (sentinel-key edge,
# all-invalid inputs, jitted-vs-eager executor) always run under the
# tier-1 gate in tests/test_data_plane.py; this module widens the
# search space when hypothesis is installed.
