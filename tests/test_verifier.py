"""The static plan verifier: the bench corpus certifies clean, and
every seeded defect class is rejected with its own distinct diagnostic
(a verifier that rejects everything for one reason certifies nothing).

Pinned Afrati–Ullman replication floors live in
``tests/data/replication_bounds.json`` — the bound is part of the
verifier's contract, so silent cost-model drift must fail loudly here.
"""

import dataclasses
import json
import math
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (ERROR, VerifierReport, all_bench_targets,
                            verify_bench_targets, verify_chain_plan,
                            verify_grid, verify_join_steps,
                            verify_partitioning, verify_replication_bound)
from repro.core import (ChainCaps, ChainQuery, JoinQuery, SimGrid,
                        chain_partitioning, chain_stats_exact,
                        default_part_capacity, mapside_cascade_chain,
                        partition_relation, plan_chain,
                        replication_lower_bound_chain,
                        replication_lower_bound_query)
from repro.core.cost_model import optimal_shares_chain, optimal_shares_query
from repro.core.relation import Relation

REPO = Path(__file__).resolve().parents[1]
BOUNDS = REPO / "tests" / "data" / "replication_bounds.json"


def small_chain(n=3, rows=64, seed=0):
    rng = np.random.default_rng(seed)
    edges = [(rng.integers(0, 16, rows).astype(np.int32),
              rng.integers(0, 16, rows).astype(np.int32))
             for _ in range(n)]
    query = ChainQuery.chain(n)
    stats = chain_stats_exact(edges)
    plan = plan_chain(stats, 8, aggregate=False)
    return query, stats, plan, edges


def partitioned_store(query, edges, P=4):
    """Partition every relation on its hop key; returns (prels, specs,
    cert)."""
    prels, specs = [], []
    for j, (s, d) in enumerate(edges):
        key = query.attrs[1] if j == 0 else query.attrs[j]
        names = (query.attrs[j], query.attrs[j + 1])
        rel = Relation.from_arrays(**{names[0]: s, names[1]: d})
        prel, _ = partition_relation(
            rel, key, P, part_capacity=default_part_capacity(len(s), P))
        prels.append(prel)
        specs.append(prel.spec)
    return prels, specs, chain_partitioning(query, specs)


# ---------------------------------------------------------------------------
# Positive: the bench corpus certifies
# ---------------------------------------------------------------------------

def test_bench_corpus_certifies_zero_errors():
    """Every plan behind the BENCH_*.json sweeps passes the plan
    checker with zero error findings (warnings allowed — they are
    headroom advisories, not soundness defects)."""
    reports = verify_bench_targets()
    assert len(reports) >= 15
    bad = [r.summary() for r in reports if not r.ok]
    assert not bad, "\n".join(bad)
    # Replication-gap metrics are recorded for every certified plan.
    assert all("replication_floor" in r.metrics for r in reports)


def test_bench_target_names_cover_all_sweeps():
    names = {t.name.split("/")[0] for t in all_bench_targets()}
    assert names == {"nway", "skew", "triangles", "mapside",
                     "join_kernels", "serving", "resilience"}


# ---------------------------------------------------------------------------
# Negative: seeded defect classes, each with a distinct diagnostic
# ---------------------------------------------------------------------------

class TestDefectClasses:
    def check(self, report, code):
        assert not report.ok
        assert code in report.codes
        f = next(f for f in report.findings if f.code == code)
        assert f.severity == ERROR
        assert len(f.message) > 20, "diagnostic must be actionable"
        return f

    def test_grid_rank_mismatch(self):
        query, stats, plan, _ = small_chain()
        rep = VerifierReport(target="t")
        verify_grid(query, "one_round", (8,), 8, rep)  # needs rank n-1=2
        self.check(rep, "GRID_RANK_MISMATCH")

    def test_shares_budget_exceeded(self):
        query, *_ = small_chain()
        rep = VerifierReport(target="t")
        verify_grid(query, "one_round", (4, 4), 8, rep)  # 16 devs > k=8
        self.check(rep, "SHARES_BUDGET_EXCEEDED")

    def test_caps_undersized(self):
        query, stats, plan, _ = small_chain()
        caps = ChainCaps(recv=1, mid=1, out=1)
        rep = verify_chain_plan(query, stats, plan, caps)
        self.check(rep, "CAPS_UNDERSIZED")

    def test_sort_merge_cap_range(self):
        query, stats, plan, _ = small_chain()
        caps = ChainCaps(recv=64, mid=128, out=0)  # zero-size buffer
        rep = verify_chain_plan(query, stats, plan, caps)
        self.check(rep, "SORT_MERGE_CAP_RANGE")

    def test_join_order_invalid(self):
        tri = JoinQuery.triangle()
        rep = VerifierReport(target="t")
        verify_join_steps(tri, (0, 2, 2), rep)
        self.check(rep, "JOIN_ORDER_INVALID")

    def test_closing_filter_dropped(self):
        """Strip the cycle-closing equality off the triangle's last
        hop — the exact bug that counts paths instead of triangles."""
        tri = JoinQuery.triangle()
        order = tri.default_join_order()
        tampered = [(rj, key, ()) for rj, key, _ in tri.join_steps(order)]
        rep = VerifierReport(target="t")
        verify_join_steps(tri, order, rep, steps=tampered)
        f = self.check(rep, "CLOSING_FILTER_DROPPED")
        assert "filter" in f.message

    def test_cert_salt_mismatch(self):
        query, _, _, edges = small_chain()
        _, specs, cert = partitioned_store(query, edges)
        bad = list(specs)
        bad[1] = dataclasses.replace(bad[1], salt=3)
        rep = VerifierReport(target="t")
        verify_partitioning(query, cert, rep, specs=bad)
        self.check(rep, "CERT_SALT_MISMATCH")

    def test_cert_partitions_mismatch(self):
        query, _, _, edges = small_chain()
        _, specs, cert = partitioned_store(query, edges)
        bad = list(specs)
        bad[1] = dataclasses.replace(bad[1], num_partitions=8)
        rep = VerifierReport(target="t")
        verify_partitioning(query, cert, rep, specs=bad)
        self.check(rep, "CERT_PARTITIONS_MISMATCH")

    def test_cert_key_dtype_mismatch(self):
        query, _, _, edges = small_chain()
        _, specs, cert = partitioned_store(query, edges)
        bad = list(specs)
        bad[1] = dataclasses.replace(bad[1], key_dtype="int64")
        rep = VerifierReport(target="t")
        verify_partitioning(query, cert, rep, specs=bad)
        self.check(rep, "CERT_KEY_DTYPE_MISMATCH")

    def test_cert_dtype_stale(self):
        """A certificate minted under the other key width proves
        nothing: the partition hash folds 64-bit keys."""
        query, _, _, edges = small_chain()
        _, _, cert = partitioned_store(query, edges)
        stale = dataclasses.replace(cert, key_dtype="int64")
        rep = VerifierReport(target="t")
        verify_partitioning(query, stale, rep)
        self.check(rep, "CERT_DTYPE_STALE")

    def test_unproven_mapside_hop(self):
        query, _, _, edges = small_chain()
        _, _, cert = partitioned_store(query, edges)
        assert all(cert.right_proven)
        broken = dataclasses.replace(
            cert, right_proven=(False,) + cert.right_proven[1:])
        rep = VerifierReport(target="t")
        verify_partitioning(query, broken, rep,
                            hop_modes=("mapside",) * (query.n_relations - 1))
        self.check(rep, "UNPROVEN_MAPSIDE_HOP")

    def test_hop_modes_arity(self):
        query, _, _, edges = small_chain()
        _, _, cert = partitioned_store(query, edges)
        rep = VerifierReport(target="t")
        verify_partitioning(query, cert, rep, hop_modes=("mapside",))
        self.check(rep, "HOP_MODES_ARITY")

    def test_repl_bound_violation(self):
        """A grid that ignores the declared budget (1×1 at k=64) prices
        below the k=64 replication floor — the impossible-cost
        inconsistency between plan.k and the executed grid."""
        rep = VerifierReport(target="t")
        verify_replication_bound((1000.0,) * 3, 64, (1, 1), rep)
        self.check(rep, "REPL_BOUND_VIOLATION")

    def test_cost_model_drift(self):
        query, stats, plan, _ = small_chain()
        stale = dataclasses.replace(
            plan, costs={**plan.costs,
                         plan.algorithm: plan.costs[plan.algorithm] * 2.0})
        caps = ChainCaps(recv=4096, mid=8192, out=8192)
        rep = verify_chain_plan(query, stats, stale, caps)
        self.check(rep, "COST_MODEL_DRIFT")

    def test_pair_index_overflow_warning(self):
        """Buffers whose worst-case pair index tops 2^31 draw a warning
        (not an error) while x64 is off."""
        query, stats, plan, _ = small_chain()
        caps = ChainCaps(recv=65536, mid=65536, out=65536)
        rep = verify_chain_plan(query, stats, plan, caps)
        assert "PAIR_INDEX_OVERFLOW" in rep.codes
        f = next(f for f in rep.findings
                 if f.code == "PAIR_INDEX_OVERFLOW")
        assert f.severity == "warning"
        assert rep.metrics["worst_pair_index"] >= 2 ** 31

    def test_defect_diagnostics_are_distinct(self):
        """Eight-plus defect classes, eight-plus distinct codes — no
        catch-all diagnostic."""
        codes = {
            "GRID_RANK_MISMATCH", "SHARES_BUDGET_EXCEEDED",
            "CAPS_UNDERSIZED", "SORT_MERGE_CAP_RANGE",
            "JOIN_ORDER_INVALID", "CLOSING_FILTER_DROPPED",
            "CERT_SALT_MISMATCH", "CERT_PARTITIONS_MISMATCH",
            "CERT_KEY_DTYPE_MISMATCH", "CERT_DTYPE_STALE",
            "UNPROVEN_MAPSIDE_HOP", "HOP_MODES_ARITY",
            "REPL_BOUND_VIOLATION", "COST_MODEL_DRIFT",
        }
        assert len(codes) >= 8


# ---------------------------------------------------------------------------
# Runtime guard: the executor rejects stale certificates too
# ---------------------------------------------------------------------------

def test_executor_rejects_stale_certificate_dtype():
    """Satellite of the verifier's CERT_DTYPE_STALE: the map-side
    lowering itself refuses a certificate minted under the other key
    width (defense in depth for stores loaded from disk)."""
    query, _, _, edges = small_chain(rows=32)
    prels, _, cert = partitioned_store(query, edges)
    stale = dataclasses.replace(cert, key_dtype="int64")
    modes = ("mapside",) * (query.n_relations - 1)
    caps = ChainCaps(recv=64, mid=256, out=512, local=64, join=256)
    with pytest.raises(ValueError, match="minted over"):
        mapside_cascade_chain(SimGrid((4,)), query, prels,
                              partitioning=stale, hop_modes=modes,
                              caps=caps)


# ---------------------------------------------------------------------------
# Pinned replication-rate bounds (triangle + 4-hop chain)
# ---------------------------------------------------------------------------

def test_pinned_replication_bounds():
    pins = json.loads(BOUNDS.read_text())
    assert {"triangle", "triangle_skewed_sizes", "chain4",
            "chain4_skewed_sizes"} <= set(pins)
    for name, pin in pins.items():
        sizes, k = tuple(pin["sizes"]), pin["k"]
        if "rel_dims" in pin:
            rel_dims = tuple(tuple(d) for d in pin["rel_dims"])
            bound = replication_lower_bound_query(rel_dims, sizes, k)
            shares = optimal_shares_query(rel_dims, sizes, k)
        else:
            bound = replication_lower_bound_chain(sizes, k)
            shares = optimal_shares_chain(sizes, k)
        assert math.isclose(bound, pin["bound"], rel_tol=1e-9), name
        assert np.allclose(shares, pin["shares"], rtol=1e-9), name


def test_triangle_bound_matches_symmetry():
    """Equal-size triangle: the optimum is the symmetric k^(1/3)
    hypercube, so the floor must be invariant under relation
    permutation."""
    dims = ((0, 1), (1, 2), (0, 2))
    b1 = replication_lower_bound_query(dims, (1000.0,) * 3, 64)
    b2 = replication_lower_bound_query(((1, 2), (0, 2), (0, 1)),
                                       (1000.0,) * 3, 64)
    assert math.isclose(b1, b2, rel_tol=1e-9)
    shares = optimal_shares_query(dims, (1000.0,) * 3, 64)
    assert np.allclose(shares, 64 ** (1 / 3), rtol=1e-6)
